"""Compiled shape-bucketed scorer runtime (kernels/ccm_scorer/jit.py).

Four contracts:
  * bucket grid — lane/event/pair rounding (powers of two, 128-lane cap);
  * padding invariance — bucketed/padded f64 jit scoring is BITWISE-equal
    to the unpadded numpy backend for arbitrary candidate counts,
    including the empty-candidate and single-task edges (property test
    when hypothesis is installed, seeded sweep otherwise);
  * recompile-count guard — a 500-event trajectory triggers at most one
    XLA trace per distinct shape bucket, so shape churn cannot silently
    reintroduce per-event tracing;
  * f32 parity tiers — the pallas_compiled path must reproduce the
    numpy backend's ASSIGNMENTS on well-separated instances and its ulp
    divergence on adversarial tiles is measured and bounded.
"""
import numpy as np
import pytest

from repro.core import CCMParams, CCMState, ccm_lb, random_phase
from repro.core.clusters import build_clusters
from repro.core.engine import ExchangeEvent, PhaseEngine
from repro.core.problem import Phase, initial_assignment
from repro.kernels.ccm_scorer import N_AV, N_PM, N_SC, SC, jit, ops, ref

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=True)


# ------------------------------------------------------------ bucket grid
def test_bucket_lanes_grid():
    assert [jit.bucket_lanes(n) for n in (1, 7, 8, 9, 16, 17, 100)] == \
        [8, 8, 8, 16, 16, 32, 128]
    # at the 128-lane boundary buckets stop doubling and grow in lanes
    assert jit.bucket_lanes(128) == 128
    assert jit.bucket_lanes(129) == 256
    assert jit.bucket_lanes(513) == 640


def test_bucket_events_and_pairs_grid():
    assert [jit.bucket_events(e) for e in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    assert jit.bucket_pairs(1) == 32     # floor = the default shortlist cap
    assert jit.bucket_pairs(32) == 32
    assert jit.bucket_pairs(33) == 64


# ----------------------------------------------------- padding invariance
def _random_tiles(rng, e_n, a_n, b_n):
    av = rng.uniform(-2, 2, (e_n, N_AV, a_n))
    bv = rng.uniform(-2, 2, (e_n, N_AV, b_n))
    pm = rng.uniform(-2, 2, (e_n, N_PM, a_n, b_n))
    sc = rng.uniform(0.1, 3.0, (e_n, N_SC))
    sc[:, SC.na] = rng.integers(0, a_n, e_n)
    sc[:, SC.nb] = rng.integers(0, b_n, e_n)
    return av, bv, pm, sc


def _assert_padding_invariant(e_n, a_n, b_n, seed):
    rng = np.random.default_rng(seed)
    av, bv, pm, sc = _random_tiles(rng, e_n, a_n, b_n)
    want = ref.score_tiles(av, bv, pm, sc)
    got = ops.ccm_score_tiles(av, bv, pm, sc, backend="jit")
    np.testing.assert_array_equal(got, want)


def test_padding_invariance_seeded_sweep():
    """Bucketed/padded jit == unpadded numpy, bit for bit, across the edge
    shapes: A/B of 1 (empty-candidate tiles), non-bucket sizes, and sizes
    straddling bucket boundaries."""
    for seed, (e_n, a_n, b_n) in enumerate(
            [(1, 1, 1), (1, 2, 9), (2, 13, 13), (3, 8, 16), (1, 17, 5),
             (2, 33, 3)]):
        _assert_padding_invariant(e_n, a_n, b_n, seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    pass
else:
    @settings(max_examples=30, deadline=None)
    @given(e_n=st.integers(1, 4), a_n=st.integers(1, 40),
           b_n=st.integers(1, 40), seed=st.integers(0, 10_000))
    def test_padding_invariance_property(e_n, a_n, b_n, seed):
        _assert_padding_invariant(e_n, a_n, b_n, seed)


def test_engine_jit_backend_bitwise_and_edges():
    """Engine-level parity incl. the empty-candidate and single-task edges:
    jit scores == numpy scores bitwise on full events and the empty event
    returns empty outputs."""
    phase = random_phase(5, num_ranks=8, num_tasks=120, num_blocks=14,
                         num_comms=260, mem_cap=4e8)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    clusters = build_clusters(state)
    empty = np.zeros(0, np.int64)
    events = []
    for r_a, r_b in ((0, 1), (2, 3), (4, 5)):
        cand_a = [empty] + clusters[r_a][:6]
        cand_b = [empty] + clusters[r_b][:6]
        pairs = [(ia, ib) for ia in range(len(cand_a))
                 for ib in range(len(cand_b)) if ia or ib]
        events.append(ExchangeEvent(r_a, r_b, cand_a, cand_b, pairs))
    events.append(ExchangeEvent(6, 7, [empty], [empty], []))  # na = nb = 0
    res_np = PhaseEngine(state, backend="numpy") \
        .batch_exchange_eval_multi(events)
    res_jit = PhaseEngine(state, backend="jit") \
        .batch_exchange_eval_multi(events)
    for (wa, wb, fe), (wa2, wb2, fe2) in zip(res_np, res_jit):
        np.testing.assert_array_equal(wa, wa2)
        np.testing.assert_array_equal(wb, wb2)
        np.testing.assert_array_equal(fe, fe2)
    assert res_jit[-1][0].shape == (0,)


def test_single_task_phase_jit():
    phase = Phase(
        task_load=np.array([2.0]), task_mem=np.array([8.0]),
        task_overhead=np.array([1.0]), task_block=np.array([0]),
        block_size=np.array([16.0]), block_home=np.array([0]),
        comm_src=np.array([0]), comm_dst=np.array([0]),
        comm_vol=np.array([3.0]),
        rank_mem_base=np.zeros(2), rank_mem_cap=np.full(2, 1e9))
    state = CCMState.build(phase, np.array([0]), PARAMS)
    clusters = build_clusters(state)
    empty = np.zeros(0, np.int64)
    ev = [ExchangeEvent(0, 1, [empty] + clusters[0], [empty], [(1, 0)])]
    res = {be: PhaseEngine(state, backend=be).batch_exchange_eval_multi(ev)
           for be in ("numpy", "jit")}
    np.testing.assert_array_equal(res["numpy"][0][0], res["jit"][0][0])
    np.testing.assert_array_equal(res["numpy"][0][1], res["jit"][0][1])
    assert res["jit"][0][2][0]


def test_gather_then_combine_is_combine_then_gather():
    """combine_work_pairs on gathered planes == combine_work on the full
    tile followed by the gather (the hot path's correctness hinge)."""
    rng = np.random.default_rng(3)
    av, bv, pm, sc = _random_tiles(rng, 2, 9, 7)
    out = ref.score_tiles(av, bv, pm, sc)
    w_a, w_b, feas = ops.combine_work(out, sc, PARAMS)
    for e in range(2):
        ia = rng.integers(0, 9, 11)
        ib = rng.integers(0, 7, 11)
        wa2, wb2, fe2 = ops.combine_work_pairs(out[e][:, ia, ib], sc[e],
                                               PARAMS)
        np.testing.assert_array_equal(wa2, w_a[e, ia, ib])
        np.testing.assert_array_equal(wb2, w_b[e, ia, ib])
        np.testing.assert_array_equal(fe2, feas[e, ia, ib])


# ------------------------------------------------- recompile-count guard
def test_recompile_count_bounded_over_trajectory():
    """Scoring a 500-event trajectory with churning candidate counts and
    shortlist sizes must trigger at most one XLA trace per distinct shape
    bucket (the bucket cache growth), not one per event."""
    phase = random_phase(9, num_ranks=10, num_tasks=160, num_blocks=18,
                         num_comms=340, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    clusters = build_clusters(state)
    engine = PhaseEngine(state, backend="jit")
    empty = np.zeros(0, np.int64)
    rng = np.random.default_rng(0)
    traces0 = jit.trace_count()
    buckets0 = jit.bucket_cache_size()
    for i in range(500):
        r_a, r_b = rng.choice(10, size=2, replace=False)
        n_a = int(rng.integers(0, min(6, len(clusters[r_a])) + 1))
        n_b = int(rng.integers(0, min(6, len(clusters[r_b])) + 1))
        cand_a = [empty] + clusters[r_a][:n_a]
        cand_b = [empty] + clusters[r_b][:n_b]
        pairs = [(ia, ib) for ia in range(n_a + 1)
                 for ib in range(n_b + 1) if ia or ib]
        if pairs:
            pairs = pairs[:int(rng.integers(1, len(pairs) + 1))]
        engine.batch_exchange_eval(r_a, r_b, cand_a, cand_b, pairs)
    new_traces = jit.trace_count() - traces0
    new_buckets = jit.bucket_cache_size() - buckets0
    assert new_traces <= max(new_buckets, 1), \
        (f"{new_traces} traces for {new_buckets} new buckets — per-event "
         "retracing has crept back in")
    # the pair-gathered layout is lane-free: candidate-count churn at one
    # event per call must stay within a handful of (E, P) buckets
    assert jit.bucket_cache_size() - buckets0 <= 4


# ------------------------------------------------------- f32 parity tiers
def test_pallas_compiled_assignment_identity_well_separated():
    """The f32 compiled path's parity bar: on well-separated instances
    (continuous loads/volumes, gaps far above f32 noise) the end-to-end
    CCM-LB assignment must be IDENTICAL to the numpy backend's.  Runs via
    the interpret fallback on hosts without a Pallas compile target —
    same f32 dtype, same 128-lane layout."""
    for seed in (11, 23):
        phase = random_phase(seed, num_ranks=6, num_tasks=90, num_blocks=12,
                             num_comms=200, mem_cap=5e8)
        params = CCMParams(delta=1e-9)
        a0 = initial_assignment(phase)
        want = ccm_lb(phase, a0, params, n_iter=2, seed=1, backend="numpy")
        got = ccm_lb(phase, a0, params, n_iter=2, seed=1,
                     backend="pallas_compiled")
        np.testing.assert_array_equal(got.assignment, want.assignment,
                                      err_msg=f"seed {seed}")
        assert got.transfers == want.transfers


def _ulps_f32(a, b):
    """Units-in-last-place distance between two f32 arrays (finite lanes)."""
    ai = np.frombuffer(np.float32(a).tobytes(), np.int32).astype(np.int64)
    bi = np.frombuffer(np.float32(b).tobytes(), np.int32).astype(np.int64)
    ai = np.where(ai < 0, np.int64(-2**31) - ai, ai)
    bi = np.where(bi < 0, np.int64(-2**31) - bi, bi)
    return np.abs(ai - bi)


def test_pallas_compiled_ulp_budget_adversarial():
    """Adversarial tiles (large dynamic range, cancellation-prone sums):
    record the max ulp divergence of the f32 path vs the f64 reference
    rounded to f32.  The budget is generous — the point is a tracked
    number, not bitwise equality (that tier belongs to f64)."""
    rng = np.random.default_rng(7)
    e_n, a_n, b_n = 2, 12, 12
    av = rng.uniform(-1e5, 1e5, (e_n, N_AV, a_n))
    bv = rng.uniform(-1e5, 1e5, (e_n, N_AV, b_n))
    pm = rng.uniform(-1e4, 1e4, (e_n, N_PM, a_n, b_n))
    sc = rng.uniform(1.0, 1e6, (e_n, N_SC))
    sc[:, SC.na] = a_n - 1
    sc[:, SC.nb] = b_n - 1
    want64 = ref.score_tiles(av, bv, pm, sc)
    got32 = ops.ccm_score_tiles(av, bv, pm, sc, backend="pallas_compiled")
    finite = np.isfinite(want64) & np.isfinite(got32)
    ulps = _ulps_f32(np.float32(want64[finite]), np.float32(got32[finite]))
    max_ulp = int(ulps.max()) if ulps.size else 0
    print(f"pallas_compiled adversarial max ulp divergence: {max_ulp}")
    # f32 accumulation over ~20-term sums with 10-decade dynamic range:
    # a few hundred ulps is expected, runaway divergence is not
    assert max_ulp < 4096, max_ulp
    # infinities (masked tail) must agree exactly
    np.testing.assert_array_equal(np.isinf(want64), np.isinf(got32))


def test_pallas_compiled_fallback_reporting():
    """Off-TPU the compiled path must degrade to f32 interpret and say so."""
    av, bv, pm, sc = _random_tiles(np.random.default_rng(0), 1, 4, 4)
    ops.ccm_score_tiles(av, bv, pm, sc, backend="pallas_compiled")
    if not jit.pallas_compiled_supported():
        assert jit.pallas_compiled_fallback()
