"""Speculative-scan stage-2 driver (core/spec.py): trajectory parity with
the host engine, strict-prefix rollback semantics (a rolled-back event is
never committed in the window that rolled it back, and is always retried),
and fleet-mode (``ccm_lb_many``) per-instance identity.

The rollback property runs as a seeded sweep always, and through
hypothesis over a wider seed space when dev deps are installed — the same
split as tests/test_incremental.py.
"""
import numpy as np
import pytest

from repro.core import CCMParams, ccm_lb, ccm_lb_many, random_phase
from repro.core.problem import initial_assignment

PARAMS = CCMParams(delta=1e-9)


def _phase(seed, ranks=8, tasks=160):
    return random_phase(seed, num_ranks=ranks, num_tasks=tasks,
                        num_blocks=3 * ranks, num_comms=4 * tasks,
                        mem_cap=1e12)


def _solo(phase, a0, **kw):
    return ccm_lb(phase, a0, PARAMS, n_iter=3, k_rounds=2, fanout=4,
                  seed=0, use_engine=True, **kw)


# ------------------------------------------------------- trajectory parity
@pytest.mark.parametrize("mode,fill,window", [
    ("scan", "disjoint", 2),
    ("scan", "disjoint", 8),
    ("scan", "greedy", 8),
    ("vmap", "disjoint", 4),
    ("vmap", "greedy", 8),
])
def test_spec_matches_host_engine(mode, fill, window):
    """Every (mode, fill, window) combination is a pure scheduling
    transform: assignment AND transfer log identical to the synchronous
    host-engine trajectory."""
    phase = _phase(11, ranks=16, tasks=320)
    a0 = initial_assignment(phase)
    ref = _solo(phase, a0)
    res = _solo(phase, a0, spec_window=window, spec_mode=mode,
                spec_fill=fill)
    np.testing.assert_array_equal(ref.assignment, res.assignment)
    assert ref.transfer_log == res.transfer_log
    assert ref.transfers == res.transfers
    np.testing.assert_allclose(ref.max_work, res.max_work)
    assert res.spec_windows > 0
    if fill == "disjoint":
        # disjoint fill is rollback-free by construction
        assert res.spec_rollbacks == 0


# ------------------------------------------------ rollback never committed
def _check_rollback_property(seed):
    """Greedy fill with n_iter=1 (one run_spec call, so window ids in the
    trace are strictly increasing and contiguous runs ARE windows).
    Returns the rollback count so the sweep can assert the property was
    actually exercised."""
    phase = _phase(seed)
    a0 = initial_assignment(phase)
    res = ccm_lb(phase, a0, PARAMS, n_iter=1, k_rounds=2, fanout=4,
                 seed=seed, use_engine=True, spec_window=8,
                 spec_fill="greedy", spec_trace=True)
    ref = ccm_lb(phase, a0, PARAMS, n_iter=1, k_rounds=2, fanout=4,
                 seed=seed, use_engine=True)
    np.testing.assert_array_equal(ref.assignment, res.assignment)
    assert ref.transfer_log == res.transfer_log

    trace = res.spec_trace
    assert trace is not None
    wids = [e[0] for e in trace]
    assert wids == sorted(wids)                   # one run_spec call
    windows = {}
    for wid, kind, r, p in trace:
        windows.setdefault(wid, []).append((kind, r, p))
    for wid, entries in windows.items():
        rolled = {(r, p) for kind, r, p in entries if kind == "rollback"}
        landed = {(r, p) for kind, r, p in entries
                  if kind in ("transfer", "commit")}
        # a rolled-back speculation never lands in the window that cut it
        assert not (rolled & landed), (wid, rolled & landed)
        # strict prefix: after the first rollback of a window, every
        # later entry of that window is a rollback too
        kinds = [kind for kind, _, _ in entries]
        if "rollback" in kinds:
            first = kinds.index("rollback")
            assert all(k == "rollback" for k in kinds[first:]), entries
            # a window that rolled anything back re-queues it, so it is
            # never the last window of the call
            assert wid < max(windows)
    # every rolled-back event is retried later in the trace
    for i, (wid, kind, r, p) in enumerate(trace):
        if kind == "rollback":
            assert any(e[2] == r and e[3] == p for e in trace[i + 1:]), \
                (wid, r, p)
    # the counters aggregate the trace
    assert res.transfers == sum(1 for e in trace if e[1] == "transfer")
    assert res.spec_rollbacks == sum(1 for e in trace
                                     if e[1] == "rollback")
    assert res.spec_windows == len(windows)
    return res.spec_rollbacks


@pytest.mark.parametrize("seed", range(8))
def test_spec_rollback_never_committed_seeded(seed):
    """Seeded sweep of the property (always runs, hypothesis or not)."""
    _check_rollback_property(seed)


def test_spec_greedy_fill_exercises_rollback():
    """The greedy property sweep must actually hit the rollback path."""
    assert sum(_check_rollback_property(s) for s in range(8)) > 0


try:  # hypothesis variant: wider seed space when dev deps are installed
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_spec_rollback_never_committed_property(seed):
        _check_rollback_property(seed)
except ImportError:  # pragma: no cover - exercised without dev deps
    pass


# ------------------------------------------------------ fleet-mode parity
def test_fleet_matches_solo_engines():
    """``ccm_lb_many`` is the same trajectories, scheduled differently:
    every instance's assignment and transfer log match its solo run."""
    n = 3
    phases = [_phase(20 + i) for i in range(n)]
    a0s = [initial_assignment(p) for p in phases]
    kw = dict(n_iter=3, k_rounds=2, fanout=4, max_candidates=12)
    fleet = ccm_lb_many(phases, a0s, PARAMS, seed=5, **kw)
    for i in range(n):
        solo = ccm_lb(phases[i], a0s[i], PARAMS, seed=5 + i,
                      use_engine=True, **kw)
        np.testing.assert_array_equal(fleet[i].assignment, solo.assignment)
        assert fleet[i].transfer_log == solo.transfer_log
        np.testing.assert_allclose(fleet[i].max_work, solo.max_work)
        assert fleet[i].engine_used


def test_fleet_explicit_seeds_and_window():
    phases = [_phase(30), _phase(31)]
    a0s = [initial_assignment(p) for p in phases]
    kw = dict(n_iter=2, k_rounds=2, fanout=4)
    fleet = ccm_lb_many(phases, a0s, PARAMS, seeds=[9, 9], window=4, **kw)
    for i in range(2):
        solo = ccm_lb(phases[i], a0s[i], PARAMS, seed=9, use_engine=True,
                      **kw)
        np.testing.assert_array_equal(fleet[i].assignment, solo.assignment)
        assert fleet[i].transfer_log == solo.transfer_log


# ----------------------------------------------------------- knob checking
def test_spec_knob_validation():
    phase = _phase(40)
    a0 = initial_assignment(phase)
    with pytest.raises(ValueError, match="spec_window"):
        ccm_lb(phase, a0, PARAMS, spec_window=0)
    with pytest.raises(ValueError, match="use_engine"):
        ccm_lb(phase, a0, PARAMS, use_engine=False, spec_window=4)
    with pytest.raises(ValueError, match="mutually"):
        ccm_lb(phase, a0, PARAMS, use_engine=True, spec_window=4,
               batch_lock_events=8)
    with pytest.raises(ValueError, match="fill"):
        ccm_lb(phase, a0, PARAMS, use_engine=True, spec_window=4,
               spec_fill="bogus")
