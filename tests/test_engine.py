"""Vectorized evaluation engine vs the scalar reference path.

Three parity layers (the contract documented in repro/core/engine.py):
  * CSR structures match a naive per-element construction;
  * the vectorized cluster build equals the seed union-find reference,
    composition AND ordering;
  * batched stage-2 scores match ``exchange_eval`` (1e-9; feasibility
    exact), batched stage-1 scores match ``approx_best_diff`` bitwise, and
    full CCM-LB runs produce identical assignments/traces on both paths.
"""
import numpy as np
import pytest

from repro.core import (CCMParams, CCMState, ccm_lb, exchange_eval,
                        random_phase)
from repro.core.clusters import (build_clusters, build_clusters_reference,
                                 summarize_clusters, summarize_rank)
from repro.core.csr import PhaseCSR, rank_segments
from repro.core.engine import (PhaseEngine, batch_peer_diffs,
                               build_summary_tables)
from repro.core.gossip import build_peer_networks
from repro.core.problem import initial_assignment
from repro.core.transfer import approx_best_diff

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=True)


def _phase(seed, ranks=5, tasks=60, blocks=8, comms=120, mem_cap=4e8):
    return random_phase(seed, num_ranks=ranks, num_tasks=tasks,
                        num_blocks=blocks, num_comms=comms, mem_cap=mem_cap)


# --------------------------------------------------------------------- CSR
def test_csr_task_edges_match_naive():
    phase = _phase(0)
    csr = PhaseCSR.from_phase(phase)
    for t in range(phase.num_tasks):
        naive = [e for e in range(phase.num_comms)
                 if phase.comm_src[e] == t or phase.comm_dst[e] == t]
        assert sorted(csr.task_edges.row(t).tolist()) == naive


def test_csr_block_tasks_and_rank_segments():
    phase = _phase(1)
    csr = PhaseCSR.from_phase(phase)
    for b in range(phase.num_blocks):
        naive = np.nonzero(phase.task_block == b)[0]
        np.testing.assert_array_equal(csr.block_tasks.row(b), naive)
    a = initial_assignment(phase, "home")
    segs = rank_segments(a, phase.num_ranks)
    for r in range(phase.num_ranks):
        np.testing.assert_array_equal(segs.row(r), np.nonzero(a == r)[0])


def test_csr_gather_concatenates_rows():
    phase = _phase(2)
    csr = PhaseCSR.from_phase(phase)
    rows = np.array([5, 0, 5, 17], np.int64)
    expect = np.concatenate([csr.task_edges.row(t) for t in rows])
    np.testing.assert_array_equal(csr.task_edges.gather(rows), expect)
    assert csr.task_edges.gather(np.zeros(0, np.int64)).size == 0


# ----------------------------------------------------------- cluster build
@pytest.mark.parametrize("seed", range(15))
def test_build_clusters_matches_reference(seed):
    phase = _phase(seed, ranks=6, tasks=80, blocks=10, comms=160,
                   mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    got = build_clusters(state)
    ref = build_clusters_reference(state)
    assert got.keys() == ref.keys()
    for r in got:
        assert len(got[r]) == len(ref[r])
        for x, y in zip(got[r], ref[r]):
            np.testing.assert_array_equal(x, y)


def test_build_clusters_incremental_matches_reference():
    phase = _phase(3, ranks=6, tasks=80, blocks=10, comms=160, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "round_robin"),
                           PARAMS)
    got = build_clusters(state, only_ranks=[1, 4],
                         max_clusters_per_rank=5)
    ref = build_clusters_reference(state, only_ranks=[1, 4],
                                   max_clusters_per_rank=5)
    for r in (1, 4):
        assert len(got[r]) == len(ref[r])
        for x, y in zip(got[r], ref[r]):
            np.testing.assert_array_equal(x, y)


def test_summarize_clusters_volumes():
    phase = _phase(4, ranks=6, tasks=80, blocks=10, comms=160, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    clusters = build_clusters(state)
    csum = summarize_clusters(state, clusters)
    for r, summaries in csum.items():
        for ci, c in enumerate(summaries):
            tasks = clusters[r][ci]
            in_c = np.zeros(phase.num_tasks, bool)
            in_c[tasks] = True
            src_in = in_c[phase.comm_src]
            dst_in = in_c[phase.comm_dst]
            assert c.vol_intra == pytest.approx(
                phase.comm_vol[src_in & dst_in].sum(), abs=1e-6)
            assert c.vol_ext == pytest.approx(
                phase.comm_vol[src_in ^ dst_in].sum(), abs=1e-6)


# -------------------------------------------------- stage-2 batched parity
@pytest.mark.parametrize("seed", range(50))
def test_batch_exchange_eval_matches_scalar(seed):
    """Engine-batched scores vs legacy exchange_eval on random phases: all
    candidate give/swap pairs of a random rank pair."""
    phase = _phase(seed, mem_cap=4e8 if seed % 2 else 1e12)
    params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                       memory_constraint=bool(seed % 3))
    mode = "round_robin" if seed % 2 else "home"
    state = CCMState.build(phase, initial_assignment(phase, mode), params)
    engine = PhaseEngine(state)
    clusters = build_clusters(state)
    r_a = seed % phase.num_ranks
    r_b = (r_a + 1 + seed % (phase.num_ranks - 1)) % phase.num_ranks
    empty = np.zeros(0, np.int64)
    cand_a = [empty] + clusters[r_a][:6]
    cand_b = [empty] + clusters[r_b][:6]
    pairs = [(ia, ib) for ia in range(len(cand_a))
             for ib in range(len(cand_b)) if ia or ib]
    agg_a = engine.cluster_aggregates(r_a, clusters[r_a])
    agg_b = engine.cluster_aggregates(r_b, clusters[r_b])
    wa, wb, feas = engine.batch_exchange_eval(r_a, r_b, cand_a, cand_b,
                                              pairs, agg_a, agg_b)
    for k, (ia, ib) in enumerate(pairs):
        ev = exchange_eval(state, cand_a[ia], cand_b[ib], r_a, r_b)
        assert bool(feas[k]) == ev.feasible, (ia, ib)
        if ev.feasible:
            np.testing.assert_allclose(wa[k], ev.work_a_after, rtol=1e-9,
                                       atol=1e-12, err_msg=f"pair {(ia, ib)}")
            np.testing.assert_allclose(wb[k], ev.work_b_after, rtol=1e-9,
                                       atol=1e-12, err_msg=f"pair {(ia, ib)}")


def test_batch_exchange_eval_after_transfers():
    """Cache/counter consistency: batched scores stay correct after state
    mutation + cluster rebuilds (the aggregate cache must invalidate)."""
    phase = _phase(7, ranks=6, tasks=100, blocks=12, comms=250, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    engine = PhaseEngine(state)
    clusters = build_clusters(state)
    rng = np.random.default_rng(0)
    empty = np.zeros(0, np.int64)
    for step in range(8):
        r_a, r_b = rng.choice(phase.num_ranks, size=2, replace=False)
        cand_a = [empty] + clusters[r_a][:5]
        cand_b = [empty] + clusters[r_b][:5]
        pairs = [(ia, ib) for ia in range(len(cand_a))
                 for ib in range(len(cand_b)) if ia or ib]
        agg_a = engine.cluster_aggregates(r_a, clusters[r_a])
        agg_b = engine.cluster_aggregates(r_b, clusters[r_b])
        wa, wb, feas = engine.batch_exchange_eval(r_a, r_b, cand_a, cand_b,
                                                  pairs, agg_a, agg_b)
        for k, (ia, ib) in enumerate(pairs):
            ev = exchange_eval(state, cand_a[ia], cand_b[ib], r_a, r_b)
            assert bool(feas[k]) == ev.feasible
            if ev.feasible:
                np.testing.assert_allclose(wa[k], ev.work_a_after,
                                           rtol=1e-9, atol=1e-12)
                np.testing.assert_allclose(wb[k], ev.work_b_after,
                                           rtol=1e-9, atol=1e-12)
        # mutate: apply the first feasible non-empty give, rebuild clusters
        for k, (ia, ib) in enumerate(pairs):
            if feas[k] and (len(cand_a[ia]) or len(cand_b[ib])):
                state.swap(cand_a[ia], r_a, cand_b[ib], r_b)
                local = build_clusters(state, only_ranks=[r_a, r_b])
                clusters[r_a] = local[r_a]
                clusters[r_b] = local[r_b]
                break


# -------------------------------------------------- stage-1 batched parity
@pytest.mark.parametrize("seed", range(10))
def test_batch_peer_diffs_bitwise_matches_scalar(seed):
    phase = _phase(seed, ranks=10, tasks=150, blocks=20, comms=300,
                   mem_cap=3e8)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    clusters = build_clusters(state)
    csum = summarize_clusters(state, clusters)
    summaries = {r: summarize_rank(state, r, csum[r])
                 for r in range(phase.num_ranks)}
    info = build_peer_networks(summaries, k_rounds=2, fanout=3, seed=seed)
    tables = build_summary_tables(summaries, PARAMS)
    for r in range(phase.num_ranks):
        peers = np.array([p for p in info[r] if p != r], np.int64)
        diffs = batch_peer_diffs(tables, r, peers, PARAMS)
        for d, p in zip(diffs, peers):
            ref = approx_best_diff(summaries[r], summaries[int(p)], PARAMS)
            assert float(d) == ref, (r, p)  # bitwise


# ------------------------------------------------------- end-to-end parity
@pytest.mark.parametrize("seed", range(5))
def test_ccmlb_engine_matches_scalar_end_to_end(seed):
    """Identical transfer traces -> bitwise-identical assignments under a
    fixed seed, engine on vs off."""
    phase = _phase(seed, ranks=12, tasks=240, blocks=30, comms=500,
                   mem_cap=5e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=seed, use_engine=False)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=seed, use_engine=True)
    assert not ref.engine_used and got.engine_used
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.lock_conflicts == ref.lock_conflicts
    assert got.max_work == ref.max_work
    assert got.total_work == ref.total_work
    assert got.imbalance == ref.imbalance


# ------------------------------------------------- batched lock events
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("batch", [2, 4, 16])
def test_ccmlb_batched_lock_events_match_sequential(seed, batch):
    """Deferred disjoint-pair scoring must reproduce the one-pair-at-a-time
    trajectory exactly on seeded imbalanced phases: same assignments, same
    transfer counts, same per-iteration traces."""
    phase = _phase(seed, ranks=12, tasks=240, blocks=30, comms=500,
                   mem_cap=5e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase, "home")  # imbalanced start
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=seed,
                 batch_lock_events=1)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=seed,
                 batch_lock_events=batch)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.lock_conflicts == ref.lock_conflicts
    assert got.max_work == ref.max_work
    assert got.total_work == ref.total_work
    assert got.imbalance == ref.imbalance


def test_ccmlb_batched_matches_scalar_reference():
    """Transitivity check straight to the seed's scalar path."""
    phase = _phase(9, ranks=10, tasks=200, blocks=24, comms=420, mem_cap=6e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=2, use_engine=False)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=2, batch_lock_events=8)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.max_work == ref.max_work


def test_batch_exchange_eval_multi_matches_single_events():
    """Scoring E disjoint events jointly (block-diagonal flow, one scorer
    call) must be bitwise-equal to scoring each event alone."""
    phase = _phase(5, ranks=8, tasks=160, blocks=16, comms=320, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "round_robin"),
                           PARAMS)
    engine = PhaseEngine(state)
    clusters = build_clusters(state)
    empty = np.zeros(0, np.int64)
    from repro.core.engine import ExchangeEvent
    events = []
    for r_a, r_b in ((0, 3), (1, 6), (2, 7)):
        cand_a = [empty] + clusters[r_a][:5]
        cand_b = [empty] + clusters[r_b][:5]
        pairs = [(ia, ib) for ia in range(len(cand_a))
                 for ib in range(len(cand_b)) if ia or ib]
        events.append(ExchangeEvent(r_a, r_b, cand_a, cand_b, pairs))
    joint = engine.batch_exchange_eval_multi(events)
    for e, (wa, wb, fe) in zip(events, joint):
        wa1, wb1, fe1 = engine.batch_exchange_eval(
            e.r_a, e.r_b, e.cand_a, e.cand_b, e.pairs)
        np.testing.assert_array_equal(wa, wa1)
        np.testing.assert_array_equal(wb, wb1)
        np.testing.assert_array_equal(fe, fe1)


def test_batch_exchange_eval_multi_rejects_overlapping_events():
    phase = _phase(6, ranks=6, tasks=80, blocks=10, comms=160, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    engine = PhaseEngine(state)
    clusters = build_clusters(state)
    empty = np.zeros(0, np.int64)
    from repro.core.engine import ExchangeEvent
    mk = lambda ra, rb: ExchangeEvent(
        ra, rb, [empty] + clusters[ra][:3], [empty] + clusters[rb][:3],
        [(1, 0)])
    with pytest.raises(ValueError, match="disjoint"):
        engine.batch_exchange_eval_multi([mk(0, 1), mk(1, 2)])
    # the failed call must roll back its label buffers: a subsequent valid
    # evaluation still matches a fresh engine bitwise
    [(wa, wb, fe)] = engine.batch_exchange_eval_multi([mk(3, 4)])
    [(wa2, wb2, fe2)] = PhaseEngine(state).batch_exchange_eval_multi(
        [mk(3, 4)])
    np.testing.assert_array_equal(wa, wa2)
    np.testing.assert_array_equal(wb, wb2)
    np.testing.assert_array_equal(fe, fe2)


def test_ccmlb_batched_requires_engine():
    phase = _phase(0)
    a0 = initial_assignment(phase)
    with pytest.raises(ValueError):
        ccm_lb(phase, a0, PARAMS, use_engine=False, batch_lock_events=4)
    with pytest.raises(ValueError):
        ccm_lb(phase, a0, PARAMS, batch_lock_events=0)


def test_ccmlb_engine_parity_commfree_degenerate():
    """beta=gamma=delta=0, no blocks/comms (the seqpack mapping) — heavy
    score ties, so selection order must match exactly."""
    rng = np.random.default_rng(0)
    costs = np.round(rng.uniform(1, 4, 60))  # many exact ties
    from repro.core.problem import Phase
    phase = Phase(
        task_load=costs, task_mem=np.zeros(60), task_overhead=np.zeros(60),
        task_block=np.full(60, -1, np.int64), block_size=np.zeros(0),
        block_home=np.zeros(0, np.int64), comm_src=np.zeros(0, np.int64),
        comm_dst=np.zeros(0, np.int64), comm_vol=np.zeros(0),
        rank_mem_base=np.zeros(6), rank_mem_cap=np.full(6, np.inf))
    params = CCMParams(alpha=1.0, beta=0.0, gamma=0.0, delta=0.0,
                       memory_constraint=False)
    a0 = (np.arange(60) % 6).astype(np.int64)
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=1, use_engine=False)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=1, use_engine=True)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.max_work == ref.max_work
