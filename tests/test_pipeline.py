"""Multi-phase pipeline orchestrator (repro/core/pipeline.py).

Parity: ``ccm_lb_pipeline`` over a phase sequence must be trajectory-
IDENTICAL to hand-chaining ``ccm_lb`` (seed + k per phase, previous output
as the next start) — CSR amortization and warm-start mapping may remove
work but never change results.  Plus unit coverage of the topology check
and the id-mapped warm start, and smoke coverage of the balance/ pipeline
entry points.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CCMParams, PipelinePhase, ccm_lb, ccm_lb_pipeline,
                        random_phase, same_topology, warm_start_assignment)
from repro.core.problem import Phase, initial_assignment

PARAMS = CCMParams(delta=1e-9)


def _drifting_phases(seed, n_phases, ranks=10, tasks=200, drift=0.06):
    base = random_phase(seed, num_ranks=ranks, num_tasks=tasks,
                        num_blocks=tasks // 8, num_comms=2 * tasks,
                        mem_cap=5e8)
    rng = np.random.default_rng(seed + 100)
    phases = [base]
    for _ in range(n_phases - 1):
        prev = phases[-1]
        phases.append(dataclasses.replace(
            prev, task_load=prev.task_load
            * rng.lognormal(0.0, drift, prev.num_tasks)))
    return phases


# ----------------------------------------------------------------- parity
@pytest.mark.parametrize("seed", range(3))
def test_pipeline_matches_manual_chaining(seed):
    phases = _drifting_phases(seed, n_phases=3)
    pipe = ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=seed)
    a = initial_assignment(phases[0], "home")
    for k, ph in enumerate(phases):
        ref = ccm_lb(ph, a, PARAMS, n_iter=2, seed=seed + k)
        run = pipe.runs[k]
        np.testing.assert_array_equal(run.result.assignment, ref.assignment)
        assert run.result.transfers == ref.transfers
        assert run.result.max_work == ref.max_work
        assert run.result.imbalance == ref.imbalance
        a = ref.assignment
    assert [r.csr_reused for r in pipe.runs] == [False, True, True]
    assert [r.warm_started for r in pipe.runs] == [False, True, True]


def test_pipeline_identical_repeated_phases_warm_start_is_noop_after_first():
    """Identical repeated phases: phase k>0 starts at phase k-1's optimum,
    so warm runs match per-phase ccm_lb chaining trajectory-exactly and
    carry the full task set."""
    base = _drifting_phases(7, n_phases=1)[0]
    phases = [base] * 4
    pipe = ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=0)
    a = initial_assignment(base, "home")
    for k in range(4):
        ref = ccm_lb(base, a, PARAMS, n_iter=2, seed=k)
        np.testing.assert_array_equal(pipe.runs[k].result.assignment,
                                      ref.assignment)
        assert pipe.runs[k].result.transfers == ref.transfers
        a = ref.assignment
    assert all(r.carried_tasks == base.num_tasks for r in pipe.runs[1:])
    assert all(r.csr_reused for r in pipe.runs[1:])
    # later phases need (far) fewer transfers than the first
    assert pipe.runs[-1].result.transfers <= pipe.runs[0].result.transfers


def test_pipeline_cold_mode_restarts_every_phase():
    phases = _drifting_phases(2, n_phases=3)
    cold = ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=5,
                           warm_start=False, reuse_csr=False)
    for k, (ph, run) in enumerate(zip(phases, cold.runs)):
        ref = ccm_lb(ph, initial_assignment(ph, "home"), PARAMS, n_iter=2,
                     seed=5 + k)
        np.testing.assert_array_equal(run.result.assignment, ref.assignment)
        assert not run.csr_reused and not run.warm_started


def test_pipeline_per_phase_params():
    phases = _drifting_phases(3, n_phases=2)
    plist = [CCMParams(delta=1e-9), CCMParams(alpha=1.0, beta=2e-9,
                                              delta=1e-9)]
    pipe = ccm_lb_pipeline(phases, plist, n_iter=2, seed=1)
    a = initial_assignment(phases[0], "home")
    for k, (ph, p) in enumerate(zip(phases, plist)):
        ref = ccm_lb(ph, a, p, n_iter=2, seed=1 + k)
        np.testing.assert_array_equal(pipe.runs[k].result.assignment,
                                      ref.assignment)
        a = ref.assignment
    with pytest.raises(ValueError, match="params sequence"):
        ccm_lb_pipeline(phases, [PARAMS], n_iter=1)


# ------------------------------------------------------------- unit pieces
def test_same_topology():
    a = _drifting_phases(4, n_phases=2)
    assert same_topology(a[0], a[1])        # load drift keeps topology
    assert same_topology(a[0], a[0])
    b = dataclasses.replace(a[0], comm_vol=a[0].comm_vol * 2.0)
    assert same_topology(a[0], b)           # volumes don't enter the CSR
    c = dataclasses.replace(
        a[0], comm_src=np.roll(a[0].comm_src, 1))
    assert not same_topology(a[0], c)
    d = dataclasses.replace(
        a[0], task_block=np.where(a[0].task_block == 0, -1,
                                  a[0].task_block))
    assert not same_topology(a[0], d)


def test_warm_start_assignment_positional_and_ids():
    prev = _drifting_phases(5, n_phases=1, ranks=6, tasks=30)[0]
    prev_assign = initial_assignment(prev, "round_robin")
    # positional: same count -> carried verbatim
    out, carried = warm_start_assignment(prev, prev_assign, prev)
    np.testing.assert_array_equal(out, prev_assign)
    assert carried == prev.num_tasks
    # id-mapped: next phase keeps tasks 10..29 and adds 5 new ones
    keep = np.arange(10, 30)
    next_phase = dataclasses.replace(
        prev,
        task_load=np.concatenate([prev.task_load[keep], np.ones(5)]),
        task_mem=np.concatenate([prev.task_mem[keep], np.zeros(5)]),
        task_overhead=np.concatenate([prev.task_overhead[keep],
                                      np.zeros(5)]),
        task_block=np.concatenate([prev.task_block[keep],
                                   np.full(5, -1, np.int64)]),
        comm_src=np.zeros(0, np.int64), comm_dst=np.zeros(0, np.int64),
        comm_vol=np.zeros(0))
    prev_ids = np.arange(30)
    next_ids = np.concatenate([keep, np.arange(100, 105)])
    out, carried = warm_start_assignment(prev, prev_assign, next_phase,
                                         prev_ids=prev_ids,
                                         next_ids=next_ids)
    assert carried == 20
    np.testing.assert_array_equal(out[:20], prev_assign[keep])
    base = initial_assignment(next_phase, "home")
    np.testing.assert_array_equal(out[20:], base[20:])
    # mismatched counts without ids: no carry
    out, carried = warm_start_assignment(prev, prev_assign, next_phase)
    assert carried == 0
    # empty previous phase with ids: falls back to base instead of crashing
    empty_prev = dataclasses.replace(
        prev, task_load=np.zeros(0), task_mem=np.zeros(0),
        task_overhead=np.zeros(0), task_block=np.zeros(0, np.int64),
        comm_src=np.zeros(0, np.int64), comm_dst=np.zeros(0, np.int64),
        comm_vol=np.zeros(0))
    out, carried = warm_start_assignment(
        empty_prev, np.zeros(0, np.int64), next_phase,
        prev_ids=np.zeros(0, np.int64), next_ids=next_ids)
    assert carried == 0
    np.testing.assert_array_equal(out, initial_assignment(next_phase,
                                                          "home"))


def test_pipeline_phase_validates_ids():
    ph = _drifting_phases(6, n_phases=1, tasks=20)[0]
    with pytest.raises(ValueError, match="one id per task"):
        PipelinePhase(ph, task_ids=np.arange(5))


def test_initial_assignment_blockless_home_mode():
    """Regression: 'home' mode on a blockless phase (pipeline-stage /
    seqpack mappings) used to index an empty block_home array."""
    k = 12
    ph = Phase(task_load=np.ones(k), task_mem=np.zeros(k),
               task_overhead=np.zeros(k),
               task_block=np.full(k, -1, np.int64),
               block_size=np.zeros(0), block_home=np.zeros(0, np.int64),
               comm_src=np.zeros(0, np.int64), comm_dst=np.zeros(0, np.int64),
               comm_vol=np.zeros(0), rank_mem_base=np.zeros(4),
               rank_mem_cap=np.full(4, np.inf))
    np.testing.assert_array_equal(initial_assignment(ph, "home"),
                                  np.arange(k) % 4)


# ------------------------------------------------- balance/ entry points
def test_rebalance_sequences_stream_smoke():
    from repro.balance import rebalance_sequences, rebalance_sequences_stream
    rng = np.random.default_rng(0)
    batches = [rng.lognormal(0.0, 0.8, 64) for _ in range(3)]
    stream = rebalance_sequences_stream(batches, 8, seed=0)
    assert len(stream) == 3
    for r in stream:
        assert r.imbalance_after <= r.imbalance_before + 1e-12
    # first step == the single-batch planner (same seed, same start)
    solo = rebalance_sequences(batches[0], 8, seed=0)
    np.testing.assert_array_equal(stream[0].assignment, solo.assignment)


def test_plan_pipeline_stages_schedule_smoke():
    pytest.importorskip("jax")
    from repro import configs
    from repro.balance import plan_pipeline_stages_schedule
    cfg = configs.get_config("tinyllama-1.1b")
    plans = plan_pipeline_stages_schedule(cfg, 4, [1024, 2048, 4096],
                                          seed=0)
    assert len(plans) == 3
    for p in plans:
        assert p.assignment.shape[0] == len(cfg.layer_kinds())
        assert np.bincount(p.assignment, minlength=4).min() >= 1


# ------------------------------------------------------------- membership
def test_pipeline_membership_joins_expand_later_phases():
    """Inter-phase elasticity: a RankJoin at phase index 1 expands that
    phase and every later one (the joined rows are resolved once and
    re-applied), the joiners end up owning work, warm-starting keeps
    working across the membership change, and the pre-join phase is
    untouched bitwise."""
    from repro.core import RankJoin

    phases = _drifting_phases(0, n_phases=3)
    pipe = ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=0,
                           membership=(RankJoin(iteration=1, count=2),))
    assert [r.result.state.phase.num_ranks for r in pipe.runs] == [10, 12, 12]
    final = pipe.runs[-1].result.assignment
    assert np.isin(final, [10, 11]).sum() > 0, "joiners attracted no work"
    assert [r.warm_started for r in pipe.runs] == [False, True, True]
    ref = ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=0)
    np.testing.assert_array_equal(pipe.runs[0].result.assignment,
                                  ref.runs[0].result.assignment)
    with pytest.raises(ValueError, match="iteration"):
        ccm_lb_pipeline(phases, PARAMS, n_iter=2, seed=0,
                        membership=(RankJoin(iteration=5),))
