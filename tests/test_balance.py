"""CCM as a framework feature: MoE expert placement (plan + function-
preserving application) and DP sequence rebalancing + straggler tracking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.balance import (apply_expert_permutation, plan_expert_placement,
                           rebalance_sequences)
from repro.balance.expert_placement import phase_from_router_stats
from repro.launch.mesh import make_local_mesh
from repro.models import moe as moe_lib
from repro.runtime.straggler import StragglerTracker

MESH = make_local_mesh(1, 1)


def _skewed_counts(l_n, e_n, seed=0):
    rng = np.random.default_rng(seed)
    counts = rng.zipf(1.5, (l_n, e_n)).astype(np.float64)
    return counts / counts.sum(1, keepdims=True) * 8192


def test_plan_reduces_imbalance():
    cfg = configs.get_config("qwen3-moe-30b-a3b")
    counts = _skewed_counts(4, 128)
    plan = plan_expert_placement(counts, cfg, 16, hbm_budget_bytes=16e9,
                                 seed=0)
    assert plan.imbalance_after <= plan.imbalance_before
    assert plan.max_work_after <= plan.max_work_before * (1 + 1e-9)
    # permutations are valid per layer
    for l in range(4):
        assert sorted(plan.permutations[l].tolist()) == list(range(128))


def test_phase_mapping_semantics():
    cfg = configs.get_config("qwen3-moe-30b-a3b")
    counts = _skewed_counts(2, 128, seed=1)
    phase = phase_from_router_stats(counts, cfg, 16, hbm_budget_bytes=16e9)
    assert phase.num_tasks == 2 * 128
    assert phase.num_blocks == 2 * 128          # expert weights = blocks
    # expert bytes: 3 GLU mats in bf16
    expected = 3 * cfg.d_model * cfg.moe_d_ff * 2
    assert phase.block_size[0] == pytest.approx(expected)
    # loads proportional to token counts
    ratio = phase.task_load[1] / max(phase.task_load[0], 1e-30)
    assert ratio == pytest.approx(counts.reshape(-1)[1] /
                                  counts.reshape(-1)[0], rel=1e-6)


def test_expert_permutation_is_function_preserving():
    """Permuting expert weights + router columns must not change outputs."""
    cfg = configs.get_smoke_config("qwen3-moe-30b-a3b")
    key = jax.random.key(0)
    from repro.models.layers import split_lp_tree
    lp = moe_lib.init_moe(key, cfg)
    params, _ = split_lp_tree(lp)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    from repro.sharding import MeshAxes
    axes = MeshAxes.for_mesh(MESH)
    y0, stats0 = moe_lib.moe_forward(params, x, cfg, MESH, axes, cfg.act)
    perm = np.random.default_rng(0).permutation(cfg.num_experts)
    p2 = apply_expert_permutation(params, perm)
    y1, stats1 = moe_lib.moe_forward(p2, x, cfg, MESH, axes, cfg.act)
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32), atol=2e-2)
    # expert counts follow the permutation
    np.testing.assert_allclose(np.asarray(stats0["expert_counts"])[perm],
                               np.asarray(stats1["expert_counts"]))


def test_seqpack_rebalances_and_respects_speed():
    rng = np.random.default_rng(0)
    costs = rng.lognormal(0, 1.2, 256)
    res = rebalance_sequences(costs, 8, seed=0)
    assert res.makespan_after <= res.makespan_before
    assert res.imbalance_after < 0.1
    # straggler-aware: rank 0 at half speed gets ~half the work
    speed = np.ones(8)
    speed[0] = 0.5
    res2 = rebalance_sequences(costs, 8, rank_speed=speed, seed=0)
    loads = np.bincount(res2.assignment, weights=costs, minlength=8)
    assert loads[0] < loads[1:].mean() * 0.75


def test_straggler_tracker():
    tr = StragglerTracker(4)
    for _ in range(10):
        tr.update(np.array([1.0, 1.0, 1.0, 2.0]))
    sf = tr.speed_factors()
    assert sf[3] == pytest.approx(0.5, rel=0.05)
    assert list(tr.stragglers()) == [3]


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "gemma2-27b",
                                  "qwen3-moe-30b-a3b"])
def test_pipeline_stage_planning(arch):
    """CCM's beta term must induce contiguous, balanced stages on
    heterogeneous layer stacks (no bespoke DP partitioner needed)."""
    from repro.balance import plan_pipeline_stages
    cfg = configs.get_config(arch)
    plan = plan_pipeline_stages(cfg, 4)
    assert plan.contiguous, plan.assignment
    assert plan.imbalance < 0.25
    assert sorted(set(plan.assignment.tolist())) == [0, 1, 2, 3]
