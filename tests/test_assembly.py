"""Gemma-analogue application (paper §VI): overdecomposition invariants,
wave-based homing, end-to-end speedups."""
import numpy as np
import pytest

from repro.assembly import build_problem, run_assembly_comparison
from repro.assembly.execute import analytic_durations, execute_task
from repro.assembly.homing import plan_homing
from repro.assembly.problem import _interaction_count


def test_overdecomposition_invariants():
    p = build_problem(1024, 8, task_limit_u=64, slabs_per_rank=2)
    assert p.num_tasks > 0
    geom = p.geom
    for t in p.tasks[:200]:
        # tasks never mix element types
        assert len(set(geom.elem_type[t.rows])) == 1
        assert len(set(geom.elem_type[t.cols])) == 1
        # zero tiles never instantiated
        assert t.n_interactions > 0
        # u-limit respected
        assert len(t.rows) <= 64 and len(t.cols) <= 64
        # slab home matches the owning rank's rows
        assert t.home_rank == p.slab_home[t.slab]


def test_zero_blocks_skipped():
    """Outer-region rows x inner-region cols (no slot) must be absent."""
    p = build_problem(1024, 8, task_limit_u=64)
    geom = p.geom
    for t in p.tasks:
        assert _interaction_count(geom, t.rows, t.cols) > 0


def test_task_execution_shapes_and_finite():
    p = build_problem(512, 4, task_limit_u=64)
    t = max(p.tasks, key=lambda t: t.quad_order)
    tile = execute_task(p, t)
    assert tile.shape == (len(t.rows), len(t.cols))
    assert np.isfinite(tile).all()
    assert np.abs(tile).max() > 0


def test_heavy_tail_exists():
    """The near-singular refinement must produce the paper's heavy tail."""
    p = build_problem(2048, 8, task_limit_u=64)
    d = analytic_durations(p)
    assert d.max() / np.median(d) > 10.0


def test_homing_waves_respect_memory():
    rng = np.random.default_rng(0)
    n = 24
    slab_bytes = rng.uniform(1e6, 5e6, n)
    home = rng.integers(0, 8, n)
    loc = rng.integers(0, 8, n)
    node_used = np.zeros(4)
    for s in range(n):
        node_used[loc[s] // 2] += slab_bytes[s]
    cap = node_used.max() + slab_bytes.max() * 2
    plan = plan_homing(slab_bytes, home, loc.copy(), ranks_per_node=2,
                       node_mem_cap=cap, node_mem_used=node_used)
    assert plan.total_bytes >= 0
    # per wave, net inflow to a node never exceeds its headroom: validated
    # structurally by the planner; here we check it converged home
    assert plan.n_off_home >= (home // 2 != loc // 2).sum()


def test_homing_swap_deadlock_detour():
    """Two full nodes that must swap -> the third-node detour fires."""
    slab_bytes = np.array([1e6, 1e6])
    home = np.array([0, 2])   # ranks: slab0 -> node0, slab1 -> node1
    loc = np.array([2, 0])    # swapped
    node_used = np.array([1e6, 1e6, 0.0])
    plan = plan_homing(slab_bytes, home, loc.copy(), ranks_per_node=2,
                       node_mem_cap=1.5e6, node_mem_used=node_used)
    assert plan.detours >= 1
    assert plan.n_off_home >= 2


def test_end_to_end_speedups():
    """Paper Fig. 5 structure: B > 1 (overdecomposition) and C >= B
    (CCM-LB), with imbalance collapsing."""
    run = run_assembly_comparison(n_unknowns=2048, num_ranks=8,
                                  durations="analytic", seed=0)
    assert run.speedup_overdecomposed > 1.2
    assert run.speedup_ccmlb > run.speedup_overdecomposed * 0.95
    assert run.imbalance_after < run.imbalance_before
    assert run.imbalance_after < 0.15


def test_cost_model_in_the_loop():
    """Train the FNN on one configuration, balance another with its
    predictions (paper §VI-D end-to-end)."""
    from repro.costmodel import train_cost_model
    from repro.costmodel.train import evaluate_cost_model
    train_p = build_problem(1536, 8, seed=1, task_limit_u=32)
    feats = train_p.features()
    durs = analytic_durations(train_p)
    noisy = durs * np.random.default_rng(0).lognormal(0, 0.1, durs.shape)
    model, _ = train_cost_model(feats, noisy, epochs=150, batch_size=128,
                                reduce_to=1600, seed=0)
    assert evaluate_cost_model(model, feats, durs)["rel_err_median"] < 0.3
    run = run_assembly_comparison(n_unknowns=1536, num_ranks=8,
                                  durations="analytic", cost_model=model,
                                  seed=2, task_limit_u=32)
    # predicted-cost balancing still beats the home layout on TRUE durations
    assert run.makespan_ccmlb <= run.makespan_overdecomposed * 1.05
    assert run.imbalance_after < run.imbalance_before
