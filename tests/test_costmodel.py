"""Cost-model FNN (paper §VI-D) + Algorithm 1 data reduction tests."""
import numpy as np
import pytest

from repro.costmodel import (StandardScaler, dynamic_data_reduce,
                             train_cost_model)
from repro.costmodel.losses import under_penalized_rmse
from repro.costmodel.network import leaky_relu
from repro.costmodel.train import evaluate_cost_model


def _synthetic_tasks(n, seed=0):
    """Features resembling the assembly tasks; duration = nonlinear fn."""
    rng = np.random.default_rng(seed)
    n_rows = rng.integers(16, 97, n)
    n_cols = rng.integers(16, 97, n)
    quad = rng.choice([4, 16, 64, 192], n, p=[0.6, 0.25, 0.1, 0.05])
    inter = (n_rows * n_cols * rng.uniform(0.3, 1.0, n)).astype(int)
    x = np.stack([n_rows, n_cols, inter, quad], 1).astype(np.float64)
    y = n_rows * n_cols * quad * 4e-9 + inter * 1e-9
    y = y * rng.lognormal(0, 0.05, n)  # machine noise
    return x, y


def test_fnn_learns_task_times():
    x, y = _synthetic_tasks(3000)
    xt, yt = _synthetic_tasks(500, seed=1)
    model, hist = train_cost_model(x, y, epochs=40, seed=0)
    metrics = evaluate_cost_model(model, xt, yt)
    assert hist["loss"][-1] < hist["loss"][0]
    assert metrics["rel_err_median"] < 0.3, metrics


def test_under_penalized_loss_barely_over_predicts():
    """Eq. 32 discounts under-prediction errors (over-predicted task times
    hurt load balance more), so the trained model should 'barely
    over-predict' — the paper's stated outcome."""
    x, y = _synthetic_tasks(2000)
    xt, yt = _synthetic_tasks(400, seed=2)
    m_plain, _ = train_cost_model(x, y, epochs=30, alpha=1.0, seed=0)
    m_under, _ = train_cost_model(x, y, epochs=30, alpha=0.15, seed=0)
    over_plain = evaluate_cost_model(m_plain, xt, yt)["over_predict_frac"]
    over_under = evaluate_cost_model(m_under, xt, yt)["over_predict_frac"]
    assert over_under < over_plain
    assert over_under < 0.2


def test_under_penalized_rmse_math():
    import jax.numpy as jnp
    pred = jnp.array([2.0, 0.0])
    truth = jnp.array([1.0, 1.0])
    # over by 1 (weight 1) and under by 1 (weight alpha)
    val = under_penalized_rmse(pred, truth, alpha=0.25)
    assert float(val) == pytest.approx(np.sqrt((1.0 + 0.25) / 2))


def test_leaky_relu_eq31():
    import jax.numpy as jnp
    x = jnp.array([-2.0, 0.0, 3.0])
    np.testing.assert_allclose(leaky_relu(x), [-0.02, 0.0, 3.0])


def test_dynamic_data_reduce_targets_overrepresented_bins():
    """Alg. 1: drops come from the fullest bins; target size respected."""
    rng = np.random.default_rng(0)
    short = rng.uniform(0.0, 0.1, 9000)   # over-represented
    long_ = rng.uniform(0.5, 1.0, 1000)
    vals = np.concatenate([short, long_])
    keep = dynamic_data_reduce(vals, 3000, n_bins=16, theta=0.5, seed=0)
    assert abs(len(keep) - 3000) <= 16
    kept = vals[keep]
    # the long tail must survive nearly intact
    assert (kept > 0.5).sum() >= 950
    # the short mass must be the one cut
    assert (kept < 0.1).sum() < 9000 * 0.35


def test_dynamic_data_reduce_noop_when_small():
    vals = np.arange(10.0)
    keep = dynamic_data_reduce(vals, 100)
    assert len(keep) == 10


def test_standard_scaler():
    rng = np.random.default_rng(0)
    x = rng.normal(5.0, 3.0, (1000, 4))
    s = StandardScaler().fit(x)
    z = s.transform(x)
    np.testing.assert_allclose(z.mean(0), 0.0, atol=1e-9)
    np.testing.assert_allclose(z.std(0), 1.0, atol=1e-9)
