"""Pallas kernels vs pure-jnp oracles (interpret=True), with shape/dtype
sweeps and chunk-boundary cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.assembly import assembly_tile, reference_tile
from repro.kernels.flash import flash_attention, reference_attention
from repro.kernels.moe_gemm import expert_gemm, reference_expert_gemm
from repro.kernels.rglru import reference_rglru, rglru_scan_op
from repro.kernels.rwkv6 import reference_wkv6, wkv6

KEY = jax.random.key(0)


def _flash_case(b, sq, skv, hq, hkv, hd, dtype, causal, window, cap,
                block_q=64, block_k=64):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=block_q, block_k=block_k, interpret=True)
    fold = lambda x, h: x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)
    ref = reference_attention(fold(q, hq), fold(k, hkv), fold(v, hkv),
                              causal=causal, window=window, softcap=cap)
    ref = ref.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,hd,causal,window,cap",
    [
        (2, 128, 128, 4, 2, 64, True, 0, 0.0),      # GQA causal
        (1, 256, 256, 4, 4, 64, True, 64, 0.0),     # sliding window
        (2, 128, 128, 8, 2, 32, True, 0, 50.0),     # softcap (gemma2)
        (1, 192, 192, 2, 1, 64, False, 0, 0.0),     # bidirectional (encoder)
        (1, 96, 160, 2, 2, 64, False, 0, 0.0),      # cross-attn shape, ragged blocks
    ])
def test_flash_matches_reference(b, sq, skv, hq, hkv, hd, causal, window,
                                 cap, dtype):
    _flash_case(b, sq, skv, hq, hkv, hd, dtype, causal, window, cap)


def test_flash_block_shape_independence():
    """Result must not depend on the VMEM tile choice."""
    outs = []
    for bq, bk in [(32, 32), (64, 128), (128, 64)]:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        outs.append(flash_attention(q, k, v, causal=True, block_q=bq,
                                    block_k=bk, interpret=True))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("s", [64, 128])
def test_wkv6_chunk_boundaries(chunk, s):
    """Chunked kernel must be exact across chunk boundaries vs the
    sequential recurrence oracle."""
    B, H, hd = 2, 2, 32
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, s, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, s, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, s, H, hd))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, s, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    out = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, s, hd)
    ref = reference_wkv6(fold(r), fold(k), fold(v), fold(lw),
                         jnp.tile(u[None], (B, 1, 1)).reshape(B * H, hd))
    ref = ref.reshape(B, H, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_wkv6_fast_decay_stability():
    """Strong decay (log_w << 0) must not over/underflow the chunked form."""
    B, s, H, hd = 1, 64, 1, 16
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, s, H, hd))
    k = jax.random.normal(ks[1], (B, s, H, hd))
    v = jax.random.normal(ks[2], (B, s, H, hd))
    lw = jnp.full((B, s, H, hd), -15.0)  # near-total decay per step
    u = jnp.zeros((H, hd))
    out = wkv6(r, k, v, lw, u, chunk=16, interpret=True)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("s,w,chunk,block_w", [
    (128, 64, 32, 32), (256, 64, 64, 64), (64, 128, 64, 32)])
def test_rglru_matches_reference(s, w, chunk, block_w):
    ks = jax.random.split(KEY, 2)
    la = -jnp.exp(jax.random.normal(ks[0], (2, s, w))) * 0.1 - 1e-3
    b = jax.random.normal(ks[1], (2, s, w))
    out = rglru_scan_op(la, b, chunk=chunk, block_w=block_w, interpret=True)
    ref = reference_rglru(la, b)
    np.testing.assert_allclose(out, ref, atol=1e-4)


@pytest.mark.parametrize("q", [4, 16, 64])
def test_assembly_tile_matches_reference(q):
    ks = jax.random.split(KEY, 3)
    pr = jax.random.uniform(ks[0], (96, 3))
    pc = jax.random.uniform(ks[1], (160, 3))
    couple = jax.random.bernoulli(ks[2], 0.7, (96, 160))
    out = assembly_tile(pr, pc, couple, quad_order=q, block_r=32, block_c=64,
                        interpret=True)
    ref = reference_tile(pr, pc, couple, q)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_assembly_matches_application_path():
    """kernel oracle == the application's execute.tile_kernel."""
    from repro.assembly.execute import tile_kernel
    ks = jax.random.split(KEY, 3)
    pr = jax.random.uniform(ks[0], (64, 3))
    pc = jax.random.uniform(ks[1], (64, 3))
    couple = jax.random.bernoulli(ks[2], 0.5, (64, 64))
    ref = reference_tile(pr, pc, couple, 16)
    app = tile_kernel(pr, pc, couple, 16)
    np.testing.assert_allclose(app, ref, atol=1e-5)


def test_assembly_mxu_distance_mode():
    """The MXU |x|^2+|y|^2-2xy expansion trades ~1e-3 relative accuracy on
    near-singular pairs for MXU throughput — bounded, documented."""
    ks = jax.random.split(KEY, 3)
    pr = jax.random.uniform(ks[0], (64, 3))
    pc = jax.random.uniform(ks[1], (64, 3))
    couple = jnp.ones((64, 64), bool)
    out = assembly_tile(pr, pc, couple, quad_order=16, mxu_distance=True,
                        block_r=32, block_c=32, interpret=True)
    ref = reference_tile(pr, pc, couple, 16)
    rel = np.abs(np.asarray(out - ref)) / (np.abs(np.asarray(ref)) + 1e-3)
    assert rel.max() < 2e-2


@pytest.mark.parametrize("e,c,d,f,dtype", [
    (4, 64, 128, 96, jnp.float32),
    (8, 32, 256, 64, jnp.bfloat16),
])
def test_expert_gemm_matches_reference(e, c, d, f, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (e, c, d)).astype(dtype)
    w = jax.random.normal(ks[1], (e, d, f)).astype(dtype)
    out = expert_gemm(x, w, block_c=32, block_f=32, block_k=64,
                      interpret=True)
    ref = reference_expert_gemm(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 10)
