"""Serving correctness: prefill + step-by-step decode must reproduce the
full-sequence forward logits — across every state family (KV cache, WKV
state, RG-LRU state, enc-dec cross caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import pad_caches
from repro.models.layers import split_lp_tree
from repro.models.model import build_model

MESH = make_local_mesh(1, 1)
ARCHS = ["tinyllama-1.1b", "gemma2-27b", "qwen3-moe-30b-a3b", "rwkv6-7b",
         "recurrentgemma-9b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = configs.get_smoke_config(arch)
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    b, prompt, extra = 2, 24, 6
    tokens = rng.integers(0, cfg.vocab_size, (b, prompt + extra)).astype(np.int32)

    # full forward logits for the whole sequence via prefill on all tokens
    _, logits_full_last = jax.jit(model.prefill_fn)(
        params, {"tokens": jnp.asarray(tokens)})

    # prefill on the prompt, then decode the remaining tokens one by one
    caches, logits = jax.jit(model.prefill_fn)(
        params, {"tokens": jnp.asarray(tokens[:, :prompt])})
    caches = pad_caches(caches, prompt + extra)
    decode = jax.jit(model.decode_fn)
    for i in range(extra):
        tok = jnp.asarray(tokens[:, prompt + i: prompt + i + 1])
        caches, logits = decode(params, caches, tok, jnp.int32(prompt + i))

    got = np.asarray(logits[:, 0], np.float32)
    want = np.asarray(logits_full_last[:, 0], np.float32)
    # compare normalized log-probs (logits may differ by dtype noise)
    got = got - got.max(-1, keepdims=True)
    want = want - want.max(-1, keepdims=True)
    np.testing.assert_allclose(got, want, atol=0.07, rtol=0.05)
    # argmax agreement is the serving-visible contract
    np.testing.assert_array_equal(got.argmax(-1), want.argmax(-1))


def test_encdec_decode_consistency():
    cfg = configs.get_smoke_config("whisper-large-v3")
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    rng = np.random.default_rng(1)
    b, s_enc, prompt, extra = 2, 32, 8, 4
    audio = jnp.asarray(rng.standard_normal((b, s_enc, cfg.d_model)) * 0.1,
                        jnp.bfloat16)
    tokens = rng.integers(0, cfg.vocab_size, (b, prompt + extra)).astype(np.int32)
    _, logits_full = jax.jit(model.prefill_fn)(
        params, {"audio_embed": audio, "tokens": jnp.asarray(tokens)})
    caches, _ = jax.jit(model.prefill_fn)(
        params, {"audio_embed": audio, "tokens": jnp.asarray(tokens[:, :prompt])})
    caches = pad_caches(caches, prompt + extra)
    decode = jax.jit(model.decode_fn)
    for i in range(extra):
        tok = jnp.asarray(tokens[:, prompt + i: prompt + i + 1])
        caches, logits = decode(params, caches, tok, jnp.int32(prompt + i))
    got = np.asarray(logits[:, 0], np.float32)
    want = np.asarray(logits_full[:, 0], np.float32)
    got = got - got.max(-1, keepdims=True)
    want = want - want.max(-1, keepdims=True)
    np.testing.assert_allclose(got, want, atol=0.07, rtol=0.05)
