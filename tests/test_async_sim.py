"""Async event-loop driver vs the synchronous reference (§IV-B).

The zero-latency parity bar: with the default ``latency=0.0`` the event
queue serializes into the synchronous driver's round-robin turn order and
``ccm_lb_async`` must be bitwise-identical to ``ccm_lb`` — assignment,
transfer sequence, work traces — on the ``ccmlb_scaling`` benchmark
instances.  Plus: the async gossip stage reproduces the synchronous
epidemic exactly at zero latency, runs are deterministic (same seed ->
same event trace), and the f64 backends stay in lockstep under latency.
"""
import numpy as np
import pytest

from repro.core import (CCMParams, ccm_lb, ccm_lb_async, make_latency,
                        random_phase, run_ccm_lb)
from repro.core.async_sim import _Sim, _run_gossip
from repro.core.ccmlb import iteration_summaries
from repro.core.ccm import CCMState
from repro.core.gossip import build_peer_networks, gossip_seed
from repro.core.problem import initial_assignment, scaling_phase

PARAMS = CCMParams(delta=1e-9)


def _assert_bitwise_equal(got, ref):
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfer_log == ref.transfer_log   # exact transfer sequence
    assert got.transfers == ref.transfers
    assert got.max_work == ref.max_work           # float lists, bitwise
    assert got.total_work == ref.total_work
    assert got.imbalance == ref.imbalance


@pytest.mark.parametrize("ranks", [16, 64])
def test_zero_latency_bitwise_identical_to_sync(ranks):
    """Acceptance bar (a): serialized zero-latency async == sync ccm_lb on
    the ccmlb_scaling instances (assignment AND transfer sequence)."""
    phase = scaling_phase(ranks)
    a0 = initial_assignment(phase)
    ref = ccm_lb(phase, a0, PARAMS, n_iter=4, k_rounds=2, fanout=4, seed=0)
    got = ccm_lb_async(phase, a0, PARAMS, n_iter=4, k_rounds=2, fanout=4,
                       seed=0)
    _assert_bitwise_equal(got, ref)
    # the serialized schedule cannot contend — uniformly with the sync
    # driver, where these are zero BY CONSTRUCTION (ProtocolStats)
    assert got.lock_conflicts == ref.lock_conflicts == 0
    assert got.yields == 0 and got.grant_chains == 0
    assert got.sim_time == 0.0 and got.messages > 0


def test_zero_latency_parity_scalar_path():
    """The parity bar holds on the scalar reference path too (the shared
    handlers are driver code, not engine code)."""
    phase = random_phase(3, num_ranks=12, num_tasks=240, num_blocks=30,
                        num_comms=480, mem_cap=1e12)
    a0 = initial_assignment(phase)
    ref = ccm_lb(phase, a0, PARAMS, n_iter=3, seed=2, use_engine=False)
    got = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=2, use_engine=False)
    _assert_bitwise_equal(got, ref)


@pytest.mark.parametrize("seed,fanout,k_rounds", [(1, 2, 1), (5, 6, 3)])
def test_zero_latency_parity_other_gossip_configs(seed, fanout, k_rounds):
    phase = random_phase(seed, num_ranks=10, num_tasks=200, num_blocks=24,
                        num_comms=400, mem_cap=1e12)
    a0 = initial_assignment(phase)
    kw = dict(n_iter=3, k_rounds=k_rounds, fanout=fanout, seed=seed)
    _assert_bitwise_equal(ccm_lb_async(phase, a0, PARAMS, **kw),
                          ccm_lb(phase, a0, PARAMS, **kw))


def test_async_gossip_matches_sync_epidemic_at_zero_latency():
    """Stage 1a in isolation: the event-queue epidemic delivers the same
    messages in the same (round) order as build_peer_networks, so the
    per-rank known-peer maps come out identical."""
    phase = random_phase(2, num_ranks=24, num_tasks=96, num_blocks=12,
                        num_comms=96, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase), PARAMS)
    _, summaries = iteration_summaries(state, phase, None)
    ref = build_peer_networks(summaries, k_rounds=2, fanout=4, seed=123)
    sim = _Sim(make_latency(0.0), np.random.default_rng(0), 10**6, None)
    info = {r: {r: summaries[r]} for r in range(phase.num_ranks)}
    dropped = _run_gossip(sim, summaries, info, k_rounds=2, fanout=4,
                          seed=123, deadline=None)
    assert dropped == 0
    assert {r: set(m) for r, m in info.items()} \
        == {r: set(m) for r, m in ref.items()}
    for r in info:          # payloads alias the same summary objects
        for p, s in info[r].items():
            assert s is ref[r][p]


def test_gossip_seed_keys_are_collision_free():
    """Satellite regression: the old per-iteration stream derivation
    ``seed * 1000 + it`` collided across nearby (seed, it) pairs —
    (1, 1000), (2, 0) and (0, 2000) all drew the SAME gossip stream.
    ``gossip_seed`` keys the SeedSequence on the pair itself, so those
    runs now see distinct epidemics (while staying deterministic)."""
    phase = random_phase(9, num_ranks=20, num_tasks=80, num_blocks=10,
                        num_comms=80, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase), PARAMS)
    _, summaries = iteration_summaries(state, phase, None)

    def net(seed):
        got = build_peer_networks(summaries, k_rounds=2, fanout=3, seed=seed)
        return {r: tuple(sorted(m)) for r, m in got.items()}

    colliding = [(1, 1000), (2, 0), (0, 2000)]
    # the arithmetic scheme collapses all three onto one stream...
    old = [net(s * 1000 + it) for s, it in colliding]
    assert old[0] == old[1] == old[2]
    # ...the pair key keeps them pairwise distinct
    new = [net(gossip_seed(s, it)) for s, it in colliding]
    assert new[0] != new[1] and new[0] != new[2] and new[1] != new[2]
    # and stays reproducible: same pair -> same epidemic
    assert net(gossip_seed(1, 1000)) == new[0]


def test_deterministic_event_trace_and_assignment():
    """Satellite: same (phase, params, seed) -> bitwise-identical event
    trace and assignment across two runs."""
    phase = random_phase(7, num_ranks=12, num_tasks=240, num_blocks=30,
                        num_comms=480, mem_cap=1e12)
    a0 = initial_assignment(phase)
    kw = dict(n_iter=3, seed=5, latency=("uniform", 0.2, 1.0),
              collect_trace=True)
    r1 = ccm_lb_async(phase, a0, PARAMS, **kw)
    r2 = ccm_lb_async(phase, a0, PARAMS, **kw)
    assert r1.events == r2.events and r1.events  # non-trivial trace
    assert r1.transfer_log == r2.transfer_log
    np.testing.assert_array_equal(r1.assignment, r2.assignment)


def test_backends_identical_under_latency():
    """Satellite: the f64 backends ("numpy"/"jit" — bitwise-equal scores
    by the scorer contract) produce identical traces even under contended
    interleavings.  batch_lock_events stays a sync-only knob."""
    phase = random_phase(7, num_ranks=12, num_tasks=240, num_blocks=30,
                        num_comms=480, mem_cap=1e12)
    a0 = initial_assignment(phase)
    kw = dict(n_iter=3, seed=5, latency=("uniform", 0.2, 1.0),
              collect_trace=True)
    r1 = ccm_lb_async(phase, a0, PARAMS, **kw)
    rj = ccm_lb_async(phase, a0, PARAMS, backend="jit", **kw)
    assert r1.events == rj.events
    assert r1.transfer_log == rj.transfer_log
    np.testing.assert_array_equal(r1.assignment, rj.assignment)
    with pytest.raises(ValueError):
        run_ccm_lb(phase, a0, PARAMS, async_mode=True, batch_lock_events=8)
    # ...and async-only knobs are rejected in sync mode, not dropped
    with pytest.raises(ValueError):
        run_ccm_lb(phase, a0, PARAMS, latency=("uniform", 0.5, 1.5))
    with pytest.raises(ValueError):
        run_ccm_lb(phase, a0, PARAMS, gossip_timeout=1.0)


def test_latency_run_improves_and_stays_feasible():
    """Under latency the trajectory differs but the optimizer contract
    holds: monotone max-work trace, feasible final assignment."""
    phase = random_phase(0, num_ranks=16, num_tasks=400, num_blocks=48,
                        num_comms=800, mem_cap=3e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    res = ccm_lb_async(phase, a0, params, n_iter=4, seed=1,
                       latency=("uniform", 0.5, 1.5))
    for a, b in zip(res.max_work, res.max_work[1:]):
        assert b <= a + 1e-9
    final = CCMState.build(phase, res.assignment, params)
    for r in range(phase.num_ranks):
        assert final.memory_feasible(r)
    assert res.sim_time > 0 and res.messages > 0


def test_gossip_timeout_drops_stale_deliveries():
    """A tight gossip deadline drops late deliveries (stale info) but the
    run stays safe and deterministic."""
    phase = random_phase(4, num_ranks=16, num_tasks=320, num_blocks=36,
                        num_comms=640, mem_cap=1e12)
    a0 = initial_assignment(phase)
    kw = dict(n_iter=2, seed=3, latency=("uniform", 0.5, 1.5))
    full = ccm_lb_async(phase, a0, PARAMS, **kw)
    cut = ccm_lb_async(phase, a0, PARAMS, gossip_timeout=1.0, **kw)
    assert full.gossip_dropped == 0
    assert cut.gossip_dropped > 0
    assert cut.messages < full.messages  # dropped deliveries don't forward
    for a, b in zip(cut.max_work, cut.max_work[1:]):
        assert b <= a + 1e-9


def test_make_latency_specs():
    rng = np.random.default_rng(0)
    assert make_latency(None)(rng, 0, 1) == 0.0
    assert make_latency("zero")(rng, 0, 1) == 0.0
    assert make_latency(2.5)(rng, 0, 1) == 2.5
    lo_hi = make_latency(("uniform", 1.0, 2.0))(rng, 0, 1)
    assert 1.0 <= lo_hi <= 2.0
    assert make_latency(("exp", 0.5))(rng, 0, 1) >= 0.0
    fn = make_latency(lambda rng, s, d: 0.25)
    assert fn(rng, 3, 4) == 0.25
    for bad in (-1.0, ("uniform", 2.0, 1.0), ("exp", -1.0), "fast", ()):
        with pytest.raises(ValueError):
            make_latency(bad)
