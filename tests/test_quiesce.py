"""Quiescence-driven incremental iterations vs the full-rebuild reference.

The PR 8 contract (repro/core/quiesce.py module docstring): the
QuiesceTracker's caches — patched cluster/summary state, per-root
epoch-keyed gossip replay, cached work-list tables, the commit-versioned
failure memo — must leave the balancer trajectory BITWISE-identical to
rebuilding everything from scratch every iteration
(``incremental=False``), on the synchronous, async, batched and
speculative drivers alike; converged iterations must do ZERO tracked
work; and because quiescence is absorbing under epoch-keyed gossip,
``quiesce_after`` early exit must not change the answer.  Property-tested
over seeded random phases (hypothesis widens the seed space when the dev
deps are installed).
"""
import numpy as np
import pytest

from repro.core import CCMParams, ccm_lb, random_phase
from repro.core.async_sim import run_ccm_lb
from repro.core.gossip import (gossip_deliver, gossip_root_key,
                               root_epidemic)
from repro.core.pipeline import ccm_lb_pipeline
from repro.core.problem import initial_assignment
from repro.core.quiesce import phase_values_equal

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=True)
ZERO_KEYS = ("cluster_rank_builds", "gossip_redraws", "worklist_rescored",
             "tables_rebuilds")


def _phase(seed, ranks=8):
    return random_phase(seed, num_ranks=ranks, num_tasks=14 * ranks,
                        num_blocks=2 * ranks, num_comms=28 * ranks,
                        mem_cap=1e12)


def _pair(phase, a0, seed, **kw):
    """(incremental result, rebuild-reference result) for one config."""
    ri = run_ccm_lb(phase, a0, PARAMS, n_iter=5, k_rounds=2, fanout=3,
                    seed=seed, incremental=True, **kw)
    rr = run_ccm_lb(phase, a0, PARAMS, n_iter=5, k_rounds=2, fanout=3,
                    seed=seed, incremental=False, **kw)
    return ri, rr


def _assert_bitwise(ri, rr, what):
    np.testing.assert_array_equal(ri.assignment, rr.assignment,
                                  err_msg=f"{what}: assignment diverged")
    assert ri.transfer_log == rr.transfer_log, \
        f"{what}: transfer log diverged"
    assert ri.max_work == rr.max_work, f"{what}: max_work trace diverged"


def _check_sync_parity(seed):
    phase = _phase(seed)
    a0 = initial_assignment(phase, "home" if seed % 2 else "round_robin")
    ri, rr = _pair(phase, a0, seed)
    _assert_bitwise(ri, rr, f"sync seed={seed}")
    assert ri.iter_transfers == rr.iter_transfers


@pytest.mark.parametrize("seed", range(6))
def test_sync_incremental_matches_rebuild(seed):
    """Seeded sweep of the property (always runs, hypothesis or not)."""
    _check_sync_parity(seed)


try:  # hypothesis variant: wider seed space when dev deps are installed
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_sync_incremental_matches_rebuild_property(seed):
        _check_sync_parity(seed)
except ImportError:  # pragma: no cover - exercised without dev deps
    pass


@pytest.mark.parametrize("kw", [dict(batch_lock_events=4),
                                dict(spec_window=8),
                                dict(use_engine=False)])
def test_config_variants_match_rebuild(kw):
    """Caching follows the engine's incremental flag per driver config;
    every variant still reproduces the rebuild reference bitwise."""
    phase = _phase(11)
    a0 = initial_assignment(phase)
    ri, rr = _pair(phase, a0, 11, **kw)
    _assert_bitwise(ri, rr, f"config {kw}")


@pytest.mark.parametrize("latency", [0.0, "uniform"])
def test_async_incremental_matches_rebuild(latency):
    phase = _phase(3)
    a0 = initial_assignment(phase)
    lat = 0.0 if latency == 0.0 else ("uniform", 0.1, 0.5)
    ri, rr = _pair(phase, a0, 3, async_mode=True, latency=lat)
    _assert_bitwise(ri, rr, f"async latency={lat}")


def test_async_zero_latency_matches_sync_incremental():
    phase = _phase(7)
    a0 = initial_assignment(phase)
    rs = run_ccm_lb(phase, a0, PARAMS, n_iter=4, k_rounds=2, fanout=3,
                    seed=7)
    ra = run_ccm_lb(phase, a0, PARAMS, n_iter=4, k_rounds=2, fanout=3,
                    seed=7, async_mode=True, latency=0.0)
    np.testing.assert_array_equal(rs.assignment, ra.assignment)
    assert rs.transfer_log == ra.transfer_log


def _converged_run(n_iter=10, **kw):
    phase = _phase(5)
    a0 = initial_assignment(phase)
    return run_ccm_lb(phase, a0, PARAMS, n_iter=n_iter, k_rounds=2,
                      fanout=3, seed=5, **kw), phase, a0


def test_converged_iterations_do_zero_work():
    """Once transfers stop, the tracker replays caches verbatim: no
    cluster builds, no gossip redraws, no work-list rescoring.  (The
    first zero-transfer iteration still folds in the last transfer's
    dirt, so the zero-work tail starts one past it.)"""
    res, _, _ = _converged_run()
    deltas = res.iter_transfers
    nz = [i for i, d in enumerate(deltas) if d]
    start = (nz[-1] + 2) if nz else 1
    assert len(deltas) - start >= 2, "phase did not converge; reseed"
    qc = res.quiesce_counters
    for k in ZERO_KEYS:
        assert qc[-1].get(k, 0) == qc[start - 1].get(k, 0), \
            f"{k} advanced across converged iterations"
    # and the iterations truly committed nothing
    assert all(d == 0 for d in deltas[start:])


def test_quiesce_after_is_lossless():
    """Quiescence is absorbing (a zero-transfer iteration reproduces
    itself: nothing dirty => same epochs => same gossip streams => same
    work lists), so early exit returns the full run's answer."""
    full, phase, a0 = _converged_run()
    early = run_ccm_lb(phase, a0, PARAMS, n_iter=10, k_rounds=2, fanout=3,
                       seed=5, quiesce_after=1)
    np.testing.assert_array_equal(early.assignment, full.assignment)
    assert len(early.iter_transfers) < len(full.iter_transfers)
    assert early.transfer_log == full.transfer_log


def test_quiesce_after_async():
    phase = _phase(5)
    a0 = initial_assignment(phase)
    full = run_ccm_lb(phase, a0, PARAMS, n_iter=8, k_rounds=2, fanout=3,
                      seed=5, async_mode=True, latency=0.0)
    early = run_ccm_lb(phase, a0, PARAMS, n_iter=8, k_rounds=2, fanout=3,
                       seed=5, async_mode=True, latency=0.0,
                       quiesce_after=1)
    np.testing.assert_array_equal(early.assignment, full.assignment)
    assert len(early.iter_transfers) <= len(full.iter_transfers)


@pytest.mark.parametrize("bad", [0, -1])
def test_quiesce_after_validated(bad):
    phase = _phase(1)
    a0 = initial_assignment(phase)
    with pytest.raises(ValueError):
        ccm_lb(phase, a0, PARAMS, n_iter=2, quiesce_after=bad)


@pytest.mark.parametrize("async_mode", [False, True])
def test_profile_stage_timings(async_mode):
    """profile=True attaches one per-stage seconds dict per iteration
    without perturbing the trajectory."""
    kw = dict(async_mode=True, latency=0.0) if async_mode else {}
    phase = _phase(2)
    a0 = initial_assignment(phase)
    plain = run_ccm_lb(phase, a0, PARAMS, n_iter=3, k_rounds=2, fanout=3,
                       seed=2, **kw)
    prof = run_ccm_lb(phase, a0, PARAMS, n_iter=3, k_rounds=2, fanout=3,
                      seed=2, profile=True, **kw)
    assert plain.stage_timings is None
    assert len(prof.stage_timings) == 3
    for tm in prof.stage_timings:
        assert {"clusters", "gossip", "work_lists"} <= tm.keys()
        assert all(v >= 0.0 for v in tm.values())
    np.testing.assert_array_equal(prof.assignment, plain.assignment)


def test_counters_reported():
    res, _, _ = _converged_run()
    assert res.memo_hits >= 0
    assert res.gossip_noop_merges > 0      # floods always collide some
    assert len(res.quiesce_counters) == len(res.iter_transfers)


def test_pipeline_carry_keeps_tracker_parity():
    """Carrying state+engine+tracker across identical phases is bitwise
    the uncarried pipeline."""
    phase = _phase(9)
    phases = [phase, phase, phase]
    cold = ccm_lb_pipeline(phases, PARAMS, warm_start=True, n_iter=3,
                           fanout=3, seed=4)
    warm = ccm_lb_pipeline(phases, PARAMS, warm_start=True,
                           carry_engine=True, n_iter=3, fanout=3, seed=4)
    assert any(r.engine_carried for r in warm.runs[1:])
    for rc, rw in zip(cold.runs, warm.runs):
        np.testing.assert_array_equal(rc.result.assignment,
                                      rw.result.assignment)
        assert rc.result.transfer_log == rw.result.transfer_log


def test_phase_values_equal():
    a = _phase(1)
    b = _phase(1)
    c = _phase(2)
    assert phase_values_equal(a, b)
    assert not phase_values_equal(a, c)


def test_root_epidemic_private_stream():
    """A root's reach depends only on its own key — rerunning it alone
    reproduces the flood bitwise (the property that lets clean roots be
    replayed from cache while dirty roots redraw)."""
    key = gossip_root_key([0, 3], 2)
    r1 = root_epidemic(16, 2, k_rounds=2, fanout=3, key=key)
    r2 = root_epidemic(16, 2, k_rounds=2, fanout=3, key=key)
    assert r1 == r2
    assert 2 not in r1      # root excluded from its own reach


def test_gossip_deliver_dedupe_counts():
    """Payloads are merged by KEY (the summary objects are opaque to the
    flood); subset payloads are counted no-ops and must not be
    forwarded."""
    s0, s1 = object(), object()
    st = {}
    known = {0: s0}
    assert not gossip_deliver(known, {0: s0}, st)   # subset: no-op
    assert st["gossip_noop_merges"] == 1
    assert gossip_deliver(known, {0: s0, 1: s1}, st)
    assert known[1] is s1
    assert st["gossip_noop_merges"] == 1


# ------------------------------------------------- death / membership purge

def test_purge_ranks_clears_all_cache_families():
    """Regression (robustness satellite): after a rank dies, purge_ranks
    must scrub it from ALL FOUR cache families — cluster/summary caches,
    gossip reach + per-rank views, work-list score tables, and the
    commit memo — and force-dirty every survivor whose gossip view
    contained it.  A stale entry in any family would let a later
    incremental fold score transfers toward a dead rank."""
    phase = _phase(0)
    a0 = initial_assignment(phase)
    res = ccm_lb(phase, a0, PARAMS, n_iter=3, seed=0, incremental=True)
    tr = res.tracker
    assert tr is not None and tr.caching
    dead = 3
    # preconditions: the caches are warm and the rank is visible in them
    assert dead in tr.reach
    assert any(dead in view for dst, view in tr.info.items() if dst != dead)
    assert tr.scores is not None and tr.clusters is not None

    tr.purge_ranks([dead])

    # family 1: cluster/summary caches emptied for the dead rank
    assert tr.clusters[dead] == [] and tr.csum[dead] == []
    # family 2: gossip — no reach entry, empty own view, gone from every
    # survivor's view
    assert dead not in tr.reach and dead not in tr.reach_key
    assert tr.info[dead] == {}
    for dst, view in tr.info.items():
        assert dead not in view or dst == dead
    # family 3: work-list score tables — own list cleared, never listed
    # as a peer elsewhere
    for r, lst in tr.scores.items():
        if r == dead:
            assert lst == []
        else:
            assert all(p != dead for (_, p) in lst)
    # family 4: commit memo — no key touching the dead rank survives
    for k in tr.memo:
        assert dead not in (k[0], k[1])
    # dirty propagation: the dead rank and every affected survivor must
    # re-enter the next fold dirty
    assert dead in tr.cluster_dirty and dead in tr.value_dirty


def test_async_kill_run_leaves_no_dead_rank_in_tracker():
    """Integration: the async driver purges the tracker when a rank dies
    mid-run — the carried tracker ends the run with no trace of it."""
    from repro.core import FaultSpec

    phase = _phase(0)
    a0 = initial_assignment(phase)
    res = run_ccm_lb(phase, a0, PARAMS, n_iter=4, k_rounds=2, fanout=3,
                     seed=0, incremental=True, async_mode=True,
                     latency=("uniform", 0.5, 1.5),
                     fault=FaultSpec(kill=((3, 1, 0.5),), seed=9))
    assert res.dead_ranks == [3]
    tr = res.tracker
    assert tr is not None
    for k in tr.memo:
        assert 3 not in (k[0], k[1])
    if tr.scores is not None:
        assert all(p != 3 for lst in tr.scores.values() for (_, p) in lst)
