"""Property-based scorer parity (hypothesis): random phases -> the scalar
``exchange_eval`` reference, the NumPy engine and the Pallas (interpret)
kernel must agree — engine-vs-kernel BITWISE on scores and feasibility,
engine-vs-scalar to the documented 1e-9 (summation-order ulps), and
CCM-LB end-to-end assignments identical across backends and lock-event
batch sizes.  Runs under the deterministic "ci" profile (conftest.py);
skipped when hypothesis (requirements-dev.txt) is absent."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (CCMParams, CCMState, ccm_lb, exchange_eval,  # noqa: E402
                        random_phase)
from repro.core.clusters import build_clusters  # noqa: E402
from repro.core.engine import ExchangeEvent, PhaseEngine  # noqa: E402
from repro.core.problem import initial_assignment  # noqa: E402


def _state(seed, ranks, tasks, mem_cap, mem_constraint):
    phase = random_phase(seed, num_ranks=ranks, num_tasks=tasks,
                         num_blocks=max(2, tasks // 8),
                         num_comms=2 * tasks, mem_cap=mem_cap)
    params = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                       memory_constraint=mem_constraint)
    mode = "home" if seed % 2 else "round_robin"
    return CCMState.build(phase, initial_assignment(phase, mode), params)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), ranks=st.integers(4, 9),
       tasks=st.integers(8, 90), tight_mem=st.booleans(),
       mem_constraint=st.booleans(), data=st.data())
def test_scorer_paths_agree_on_random_phases(seed, ranks, tasks, tight_mem,
                                             mem_constraint, data):
    """ref (scalar exchange_eval), NumPy engine and Pallas (interpret)
    agree on every candidate pair of a random disjoint event batch —
    including events whose ranks hold no clusters (empty candidates) and
    tiny phases where ranks own a single task."""
    state = _state(seed, ranks, tasks, 3e8 if tight_mem else 1e12,
                   mem_constraint)
    clusters = build_clusters(state)
    perm = data.draw(st.permutations(list(range(ranks))))
    n_events = data.draw(st.integers(1, ranks // 2))
    empty = np.zeros(0, np.int64)
    events = []
    for k in range(n_events):
        r_a, r_b = perm[2 * k], perm[2 * k + 1]
        cand_a = [empty] + clusters[r_a][:5]
        cand_b = [empty] + clusters[r_b][:5]
        pairs = [(ia, ib) for ia in range(len(cand_a))
                 for ib in range(len(cand_b)) if ia or ib]
        events.append(ExchangeEvent(r_a, r_b, cand_a, cand_b, pairs))

    res_np = PhaseEngine(state, backend="numpy") \
        .batch_exchange_eval_multi(events)
    res_pl = PhaseEngine(state, backend="pallas") \
        .batch_exchange_eval_multi(events)
    res_jit = PhaseEngine(state, backend="jit") \
        .batch_exchange_eval_multi(events)
    for e, (wa, wb, fe), (wa2, wb2, fe2), (wa3, wb3, fe3) in zip(
            events, res_np, res_pl, res_jit):
        # f64 engine backends: bitwise
        np.testing.assert_array_equal(wa, wa2)
        np.testing.assert_array_equal(wb, wb2)
        np.testing.assert_array_equal(fe, fe2)
        np.testing.assert_array_equal(wa, wa3)
        np.testing.assert_array_equal(wb, wb3)
        np.testing.assert_array_equal(fe, fe3)
        # engine vs scalar reference: documented 1e-9, feasibility exact
        for k, (ia, ib) in enumerate(e.pairs):
            ev = exchange_eval(state, e.cand_a[ia], e.cand_b[ib],
                               e.r_a, e.r_b)
            assert bool(fe[k]) == ev.feasible, (e.r_a, e.r_b, ia, ib)
            if ev.feasible:
                np.testing.assert_allclose(wa[k], ev.work_a_after,
                                           rtol=1e-9, atol=1e-12)
                np.testing.assert_allclose(wb[k], ev.work_b_after,
                                           rtol=1e-9, atol=1e-12)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), batch=st.integers(2, 6))
def test_ccmlb_end_to_end_assignment_parity(seed, batch):
    """Full CCM-LB on random phases: scalar path, NumPy engine (batched and
    unbatched) and Pallas backend all land on the same assignment."""
    phase = random_phase(seed, num_ranks=6, num_tasks=72, num_blocks=10,
                         num_comms=150, mem_cap=5e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    runs = {
        "scalar": ccm_lb(phase, a0, params, n_iter=2, seed=seed,
                         use_engine=False),
        "engine": ccm_lb(phase, a0, params, n_iter=2, seed=seed),
        "batched": ccm_lb(phase, a0, params, n_iter=2, seed=seed,
                          batch_lock_events=batch),
        "pallas": ccm_lb(phase, a0, params, n_iter=2, seed=seed,
                         backend="pallas", batch_lock_events=batch),
        "jit": ccm_lb(phase, a0, params, n_iter=2, seed=seed,
                      backend="jit", batch_lock_events=batch),
    }
    base = runs["scalar"]
    for name, run in runs.items():
        np.testing.assert_array_equal(run.assignment, base.assignment,
                                      err_msg=name)
        assert run.transfers == base.transfers, name
        assert run.max_work == base.max_work, name
