"""Incremental engine state vs from-scratch rebuilds.

The PR 3 contract (repro/core/engine.py module docstring): the engine's
transfer-listener-maintained per-rank segments, the segment-fed incremental
cluster rebuild, and the deferred grant chains must be BITWISE-equivalent
to re-deriving everything from the assignment — property-tested over
arbitrary random transfer sequences (hypothesis when available, a seeded
sweep otherwise) and end-to-end over full CCM-LB runs.
"""
import numpy as np
import pytest

from repro.core import (CCMParams, CCMState, ccm_lb, random_phase)
from repro.core.clusters import build_clusters
from repro.core.engine import PhaseEngine
from repro.core.problem import initial_assignment

PARAMS = CCMParams(alpha=1.0, beta=1e-9, gamma=1e-11, delta=1e-9,
                   memory_constraint=True)


def _random_transfer_sequence(state, engine, rng, n_moves):
    """Apply ``n_moves`` random (possibly multi-task) transfers/swaps
    through the state's mutation API (exercising the engine's hook)."""
    ph = state.phase
    for _ in range(n_moves):
        occupied = np.unique(state.assignment)
        r_from = int(rng.choice(occupied))
        r_to = int(rng.integers(ph.num_ranks))
        if r_to == r_from:
            r_to = (r_from + 1) % ph.num_ranks
        tasks = np.nonzero(state.assignment == r_from)[0]
        take = rng.integers(1, min(4, tasks.size) + 1)
        moved = rng.choice(tasks, size=take, replace=False)
        if rng.random() < 0.3:  # sometimes a swap (two listener firings)
            back_pool = np.nonzero(state.assignment == r_to)[0]
            back = (rng.choice(back_pool, size=1)
                    if back_pool.size else np.zeros(0, np.int64))
            state.swap(moved, r_from, back, r_to)
        else:
            state.apply_transfer(moved, r_from, r_to)


def _assert_segments_exact(state, engine):
    for r in range(state.phase.num_ranks):
        np.testing.assert_array_equal(
            engine.rank_tasks(r), np.nonzero(state.assignment == r)[0],
            err_msg=f"rank {r} segment diverged")


def _check_incremental_invariants(seed):
    rng = np.random.default_rng(seed)
    phase = random_phase(seed, num_ranks=6, num_tasks=60, num_blocks=8,
                         num_comms=120, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(
        phase, "home" if seed % 2 else "round_robin"), PARAMS)
    engine = PhaseEngine(state)
    for step in range(6):
        _random_transfer_sequence(state, engine, rng, n_moves=3)
        _assert_segments_exact(state, engine)
        # segment-fed incremental rebuild == assignment-scan rebuild,
        # composition AND order, for a random rank pair
        r1, r2 = rng.choice(phase.num_ranks, size=2, replace=False)
        got = build_clusters(state, only_ranks=[int(r1), int(r2)],
                             rank_tasks=engine.rank_tasks)
        ref = build_clusters(state, only_ranks=[int(r1), int(r2)])
        for r in (int(r1), int(r2)):
            assert len(got[r]) == len(ref[r])
            for x, y in zip(got[r], ref[r]):
                np.testing.assert_array_equal(x, y)
        # engine aggregates match a fresh engine's on the rebuilt lists
        agg = engine.cluster_aggregates(int(r1), got[int(r1)])
        fresh = PhaseEngine(state).cluster_aggregates(int(r1), got[int(r1)])
        np.testing.assert_array_equal(agg.loads, fresh.loads)
        np.testing.assert_array_equal(agg.blk_ci, fresh.blk_ci)
        np.testing.assert_array_equal(agg.blk_ids, fresh.blk_ids)
        np.testing.assert_array_equal(agg.blk_cnts, fresh.blk_cnts)
        assert agg.blk_map == fresh.blk_map


# ---------------------------------------------------------- seeded fallback
@pytest.mark.parametrize("seed", range(12))
def test_incremental_segments_match_rebuild_seeded(seed):
    """Seeded sweep of the property (always runs, hypothesis or not)."""
    _check_incremental_invariants(seed)


try:  # hypothesis variant: wider seed space when dev deps are installed
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_incremental_segments_match_rebuild_property(seed):
        _check_incremental_invariants(seed)
except ImportError:  # pragma: no cover - exercised without dev deps
    pass


# ----------------------------------------------------- aggregate cache caps
def test_cluster_aggregates_limit_serves_prefixes():
    phase = random_phase(3, num_ranks=5, num_tasks=80, num_blocks=10,
                         num_comms=160, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    engine = PhaseEngine(state)
    clusters = build_clusters(state)[0]
    full = engine.cluster_aggregates(0, clusters)
    lim = engine.cluster_aggregates(0, clusters, limit=3)
    assert lim is full  # cached full table serves any limited request
    engine2 = PhaseEngine(state)
    lim3 = engine2.cluster_aggregates(0, clusters, limit=3)
    assert lim3.loads.shape[0] == min(3, len(clusters))
    np.testing.assert_array_equal(lim3.loads, full.loads[:3])
    # a larger request than the cached limit recomputes
    lim5 = engine2.cluster_aggregates(0, clusters, limit=5)
    np.testing.assert_array_equal(lim5.loads, full.loads[:5])
    full2 = engine2.cluster_aggregates(0, clusters)
    np.testing.assert_array_equal(full2.loads, full.loads)


# ------------------------------------------------- mem_overhead_max upkeep
def _assert_overhead_bitwise(state):
    """Incrementally-maintained overhead maxima (and the task counts that
    guard the rescan) vs a from-scratch ``assignment == r`` rebuild."""
    ref = CCMState.build(state.phase, state.assignment, state.params)
    np.testing.assert_array_equal(state.mem_overhead_max,
                                  ref.mem_overhead_max)
    np.testing.assert_array_equal(
        state.task_count,
        np.bincount(state.assignment, minlength=state.phase.num_ranks))


def test_mem_overhead_max_incremental_paths():
    """apply_transfer's O(1)/rescan-on-demand mem_overhead_max upkeep is
    bitwise the full scan on every structural path: receiver grows toward
    the moved max, sender loses its maximum (rescan), sender empties
    (pinned to 0.0), and a previously-empty receiver repopulates."""
    phase = random_phase(21, num_ranks=4, num_tasks=24, num_blocks=4,
                         num_comms=30, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "round_robin"),
                           PARAMS)

    # sender rescan: move rank 0's max-overhead task away (receiver grows)
    r0 = np.nonzero(state.assignment == 0)[0]
    top = r0[np.argmax(phase.task_overhead[r0])]
    state.apply_transfer(np.array([top], np.int64), 0, 1)
    _assert_overhead_bitwise(state)

    # sender empties (elastic-shrink path): overhead pinned to 0.0
    r2 = np.nonzero(state.assignment == 2)[0]
    assert r2.size
    state.apply_transfer(r2, 2, 3)
    assert state.mem_overhead_max[2] == 0.0
    _assert_overhead_bitwise(state)

    # empty receiver repopulates: arriving max is taken outright
    r3 = np.nonzero(state.assignment == 3)[0][:2]
    state.apply_transfer(r3, 3, 2)
    _assert_overhead_bitwise(state)


@pytest.mark.parametrize("seed", range(6))
def test_mem_overhead_max_random_sweep_bitwise(seed):
    """Random multi-task transfer/swap sequences, ending in a full rank
    drain: incremental mem_overhead_max stays bitwise-equal to a
    from-scratch rescan after every mutation."""
    rng = np.random.default_rng(seed)
    phase = random_phase(seed, num_ranks=5, num_tasks=40, num_blocks=6,
                         num_comms=80, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(
        phase, "home" if seed % 2 else "round_robin"), PARAMS)
    engine = PhaseEngine(state)
    for _ in range(8):
        _random_transfer_sequence(state, engine, rng, n_moves=2)
        _assert_overhead_bitwise(state)
    occupied = np.unique(state.assignment)
    r = int(occupied[0])
    dest = int(occupied[-1]) if occupied.size > 1 else (r + 1) % 5
    state.apply_transfer(np.nonzero(state.assignment == r)[0], r, dest)
    assert state.mem_overhead_max[r] == 0.0
    _assert_overhead_bitwise(state)


# -------------------------------------------------------------- end to end
@pytest.mark.parametrize("seed", range(4))
def test_ccmlb_incremental_matches_rebuild_end_to_end(seed):
    """incremental=True (default) vs incremental=False (full re-gather
    reference): identical assignments, transfers, traces."""
    phase = random_phase(seed, num_ranks=12, num_tasks=240, num_blocks=30,
                         num_comms=500, mem_cap=5e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase, "home")
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=seed, incremental=False)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=seed)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.lock_conflicts == ref.lock_conflicts
    assert got.max_work == ref.max_work
    assert got.imbalance == ref.imbalance


def test_ccmlb_incremental_batched_matches_scalar():
    """Transitivity: incremental + batched lock events + deferred grant
    chains against the seed's scalar path."""
    phase = random_phase(11, num_ranks=10, num_tasks=200, num_blocks=24,
                         num_comms=420, mem_cap=6e8)
    params = CCMParams(delta=1e-9)
    a0 = initial_assignment(phase)
    ref = ccm_lb(phase, a0, params, n_iter=3, seed=2, use_engine=False)
    got = ccm_lb(phase, a0, params, n_iter=3, seed=2, batch_lock_events=8)
    np.testing.assert_array_equal(got.assignment, ref.assignment)
    assert got.transfers == ref.transfers
    assert got.max_work == ref.max_work


@pytest.mark.parametrize("yield_first", [False, True])
def test_handle_grant_deferred_matches_reference(yield_first):
    """The deferred grant-chain drain must reproduce the scalar
    ``_handle_grant`` chain exactly: same transfers, same end state, same
    re-activation order.

    The round-robin event loop releases every lock within the turn that
    took it, so queued requesters (hence chains) cannot arise through
    ``ccm_lb`` itself — the chain machinery exists for protocol fidelity
    (paper Fig. 1 lines 42-49) and is driven here with a hand-built lock
    state: rank 5 releases rank 2 with requesters [0, 3] queued
    (``yield_first`` additionally locks rank 0 so it must yield, 1 <= 2)."""
    from collections import deque

    from repro.core.ccmlb import (ProtocolStats, _PendingEvent,
                                  _handle_grant, _handle_grant_deferred,
                                  _rebuild_local)
    from repro.core.engine import ExchangeEvent
    from repro.core.locks import LockManager
    from repro.core.transfer import select_best, shortlist_pairs

    params = CCMParams(delta=1e-9)

    def scenario():
        phase = random_phase(17, num_ranks=6, num_tasks=120, num_blocks=14,
                             num_comms=240, mem_cap=1e12)
        state = CCMState.build(phase, initial_assignment(phase, "home"),
                               params)
        engine = PhaseEngine(state)
        clusters = build_clusters(state)
        locks = LockManager(phase.num_ranks)
        p = 2
        locks.locked_by[p] = 5
        # queue entries are (requester, req_id); req_id None = untracked
        # (the sync driver's path — tokens only matter under faults)
        locks.queue[p] = deque([(0, None), (3, None)])
        if yield_first:
            locks.locked_by[0] = 1      # 1 <= 2 -> rank 0 must yield
        work_lists = {r: deque([(1.0, p)]) for r in range(phase.num_ranks)}
        active = deque()
        nxt = locks.release(5, p)
        assert nxt == 0
        return state, engine, clusters, locks, work_lists, active, nxt, p

    # --- reference: scalar chain drain ---------------------------------
    state, engine, clusters, locks, wl, active, nxt, p = scenario()
    stats_ref = ProtocolStats()
    n_ref = _handle_grant(nxt, p, state, clusters, locks, wl, active,
                          12, None, engine, stats_ref)
    a_ref, act_ref = state.assignment.copy(), list(active)
    assert stats_ref.transfers == n_ref

    # --- deferred drain through the batched machinery -------------------
    state, engine, clusters, locks, wl, active, nxt, p = scenario()
    pending, busy, n_def = [], set(), [0]

    def flush():
        if not pending:
            return
        results = engine.batch_exchange_eval_multi([
            ExchangeEvent(e.r, e.p, e.cand_a, e.cand_b, e.pairs,
                          e.agg_a, e.agg_b) for e in pending])
        for e, (wa, wb, fe) in zip(pending, results):
            best = select_best(e.cand_a, e.cand_b, e.pairs, wa, wb, fe,
                               e.w_before)
            if best is not None:
                state.swap(best.tasks_ab, e.r, best.tasks_ba, e.p)
                n_def[0] += 1
                _rebuild_local(state, clusters, engine, None, e.r, e.p)
        pending.clear()
        busy.clear()

    def defer(r, pp):
        cand_a, cand_b, pairs, agg_a, agg_b = shortlist_pairs(
            state, clusters[r], clusters[pp], r, pp, 12, engine=engine)
        w_before = max(state.work(r), state.work(pp))
        pending.append(_PendingEvent(r, pp, cand_a, cand_b, pairs,
                                     agg_a, agg_b, w_before))
        busy.update((r, pp))

    _handle_grant_deferred(nxt, p, state, locks, wl, active, busy, defer,
                           flush, ProtocolStats())
    flush()

    assert n_ref >= 1              # the scenario actually transfers
    assert n_def[0] == n_ref
    np.testing.assert_array_equal(state.assignment, a_ref)
    assert list(active) == act_ref


def test_transfer_listener_fires_on_every_mutation():
    phase = random_phase(5, num_ranks=4, num_tasks=40, num_blocks=6,
                         num_comms=80, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    seen = []
    state.add_transfer_listener(lambda t, a, b: seen.append((len(t), a, b)))
    tasks = np.nonzero(state.assignment == 0)[0]
    assert tasks.size
    state.apply_transfer(tasks, 0, 1)
    back = np.nonzero(state.assignment == 1)[0][:1]
    state.swap(np.zeros(0, np.int64), 0, back, 1)  # one-sided swap
    assert seen == [(tasks.size, 0, 1), (1, 1, 0)]


def test_discarded_engine_listener_is_collected():
    """Bound-method listeners are weak: a throwaway engine on a long-lived
    state must not stay pinned (and spliced) forever."""
    import gc

    phase = random_phase(5, num_ranks=4, num_tasks=40, num_blocks=6,
                         num_comms=80, mem_cap=1e12)
    state = CCMState.build(phase, initial_assignment(phase, "home"), PARAMS)
    keeper = PhaseEngine(state)
    for _ in range(3):
        PhaseEngine(state)      # discarded immediately
    gc.collect()
    tasks = np.nonzero(state.assignment == 0)[0][:1]
    state.apply_transfer(tasks, 0, 1)   # prunes dead entries
    assert len(state._transfer_listeners) == 1
    _assert_segments_exact(state, keeper)
