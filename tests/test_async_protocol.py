"""Property-based §IV-B protocol-safety suite for the async driver.

Invariants, checked after EVERY stage-2 event via the ``on_event`` probe
and at termination:

  * safety — each rank holds at most one lock at any time NET of releases
    in flight (a yielding/finished holder sends RELEASE and moves on; the
    target's ``locked_by`` keeps the old holder of record until the
    message arrives — correct message-passing behavior, so the probe
    reconstructs in-flight releases from the event stream), each rank is
    locked by at most one holder (single-slot ``locked_by``), and every
    transfer executes under mutual exclusion (asserted inside the driver
    itself: ``locked_by[p] == r`` at evaluation time);
  * no lost or duplicated transfers — the transfer log replays from the
    initial assignment to exactly the returned one (every entry's source
    rank must match at replay time, so duplication/loss both fail);
  * liveness / deadlock-freedom — the event loop terminates with all
    mailboxes drained and the lock table quiescent (asserted inside
    ``_run_stage2``; the ``max_events`` guard turns a non-terminating
    protocol bug into a loud RuntimeError instead of a hang);
  * optimizer contract — the per-iteration max-work trace is monotone and
    the final max work lands within a tolerance band of the synchronous
    result (empirically the worst observed ratio over the sweep space is
    ~1.03; the band asserts 1.15).

Plus the coverage-of-dead-branches pin: on a fixed contended instance the
async driver MUST produce lock conflicts, yields and a grant chain of
length >= 2 — so the §IV-B branches (structurally unreachable through the
synchronous round-robin drivers) can never silently go dead again.
"""
from collections import Counter

import numpy as np
import pytest

from repro.core import (CCMParams, FaultSpec, LivelockError, RankJoin,
                        ccm_lb, ccm_lb_async, random_phase, run_ccm_lb)
from repro.core.async_sim import FAIL, GRANT, RELEASE, TIMEOUT
from repro.core.problem import initial_assignment, scaling_phase
from repro.runtime.fault import NodeFailure, RankDeath

PARAMS = CCMParams(delta=1e-9)
LATENCIES = (0.0, 0.2, ("uniform", 0.1, 0.6), ("uniform", 0.5, 1.5),
             ("exp", 0.7))


def _replay(a0: np.ndarray, transfer_log) -> np.ndarray:
    """Replay the mutation log; asserts every entry's source rank matches
    (a lost, duplicated or reordered-across-dependency transfer fails)."""
    a = a0.copy()
    for tasks, r_from, r_to in transfer_log:
        idx = list(tasks)
        assert (a[idx] == r_from).all(), \
            f"transfer {tasks}: {r_from}->{r_to} does not match replay state"
        a[idx] = r_to
    return a


def _check_protocol_safety(seed: int, lat_index: int):
    phase = random_phase(seed, num_ranks=8, num_tasks=160, num_blocks=20,
                         num_comms=320, mem_cap=1e12)
    a0 = initial_assignment(phase, "home" if seed % 2 else "round_robin")
    latency = LATENCIES[lat_index % len(LATENCIES)]
    events = [0]
    # (holder, target) pairs whose RELEASE is in flight: the grant handler
    # always sends RELEASE before returning (kind 2 == GRANT), and the
    # release lands when its event processes (kind 3 == RELEASE)
    pending_release = set()

    def probe(time, kind, src, dst, locks, state):
        events[0] += 1
        if kind == GRANT:                   # processed at dst: holder moves
            pending_release.add((dst, src))  # on, its RELEASE is in flight
        elif kind == RELEASE:               # landed at dst
            pending_release.discard((src, dst))
        for h in range(locks.n_ranks):
            live = [t for t in locks.held_by(h)
                    if (h, t) not in pending_release]
            assert len(live) <= 1, \
                f"rank {h} holds live locks {live} at t={time}"

    res = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=seed,
                       latency=latency, on_event=probe)
    assert events[0] > 0
    # no lost/duplicated transfers: the log replays to the final assignment
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    assert len(res.transfer_log) >= res.transfers  # swaps log two entries
    # monotone improvement per iteration
    for a, b in zip(res.max_work, res.max_work[1:]):
        assert b <= a + 1e-9
    # tolerance band vs the synchronous trajectory
    ref = ccm_lb(phase, a0, PARAMS, n_iter=3, seed=seed)
    assert res.max_work[-1] <= ref.max_work[-1] * 1.15 + 1e-9, \
        (res.max_work[-1], ref.max_work[-1], latency)


# ---------------------------------------------------------- seeded fallback
@pytest.mark.parametrize("seed", range(10))
def test_protocol_safety_seeded(seed):
    """Seeded sweep of the property (always runs, hypothesis or not)."""
    _check_protocol_safety(seed, lat_index=seed)


try:  # hypothesis variant: wider seed/latency space with dev deps
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 100_000),
           lat_index=st.integers(0, len(LATENCIES) - 1))
    def test_protocol_safety_property(seed, lat_index):
        _check_protocol_safety(seed, lat_index)
except ImportError:  # pragma: no cover - exercised without dev deps
    pass


# --------------------------------------------------- dead-branch coverage
def _contended_instance():
    """Half the ranks start empty, so stage 1 points many loaded ranks at
    the same underloaded peers and latency windows overlap their lock
    requests — conflicts, yields and multi-hop grant chains all fire."""
    phase = random_phase(1, num_ranks=16, num_tasks=400, num_blocks=48,
                         num_comms=800, mem_cap=1e12)
    a0 = (np.arange(phase.num_tasks) % 8).astype(np.int64)
    return phase, a0


def test_dead_branches_are_reachable_async():
    """Coverage pin (satellite): the §IV-B branches must actually fire on
    this fixed seeded instance — lock conflicts, line-45 yields, and a
    grant chain of >= 2 consecutive queue handoffs."""
    phase, a0 = _contended_instance()
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=4, seed=3, fanout=6,
                       latency=("uniform", 0.5, 1.5))
    assert res.lock_conflicts > 0
    assert res.yields > 0
    assert res.grant_chains > 0
    assert res.max_grant_chain >= 2
    assert res.transfers > 0
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    # and the balancer still does its job under contention
    assert res.imbalance[-1] < res.imbalance[0] * 0.5


def test_sync_driver_conflicts_structurally_zero():
    """The documented limitation the async driver exists to close: the
    synchronous round-robin loop releases every lock within the turn that
    took it, so even the contended instance cannot produce conflicts,
    yields or chains there (uniform accounting via the shared handlers)."""
    phase, a0 = _contended_instance()
    for kw in (dict(), dict(use_engine=False), dict(batch_lock_events=8)):
        res = ccm_lb(phase, a0, PARAMS, n_iter=4, seed=3, fanout=6, **kw)
        assert res.lock_conflicts == 0
        assert res.yields == 0
        assert res.grant_chains == 0 and res.max_grant_chain == 0


def test_max_events_guard_raises_not_hangs():
    """A liveness bug must surface as RuntimeError, not a silent hang."""
    phase, a0 = _contended_instance()
    with pytest.raises(RuntimeError, match="events"):
        ccm_lb_async(phase, a0, PARAMS, n_iter=2, seed=3,
                     latency=("uniform", 0.5, 1.5), max_events=50)


def test_yield_retries_are_bounded():
    """max_retries bounds re-queues: with zero retries allowed a yielding
    rank drops the attempt instead of looping, the drop is COUNTED (the
    house "no silent caps" rule — satellite bugfix), and the run still
    terminates safely."""
    phase, a0 = _contended_instance()
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=3, fanout=6,
                       latency=("uniform", 0.5, 1.5), max_retries=0)
    assert res.yields > 0
    assert res.retries_exhausted > 0   # every yield at cap 0 is a drop
    assert res.retries_exhausted == res.yields
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)


# ------------------------------------------------------------ fault suite
#
# Invariants under an ACTIVE FaultSpec (the faulted parity bar: invariant-
# preserving, not trajectory-identical):
#   * at most one live lock per rank — "live" reconstructed conservatively
#     from the event stream (grants consumed minus releases landed, plus a
#     slack for timeout-aborted grants the probe cannot attribute to a
#     peer: over-discounting can only weaken the check, never false-fire);
#   * transfers never target a dead rank (transfer-listener assert) and the
#     final assignment strands no task on one;
#   * transfer-log replay == final assignment (lost/duplicated mutations
#     both fail the source-rank match), crash-recovery moves included;
#   * quiescent termination (asserted inside the driver at every stage-end
#     barrier, wedge reclamation included).

FAULT_LAT = ("uniform", 0.1, 2.0)


def _run_faulted(spec: FaultSpec, *, seed=3, n_iter=3, max_retries=4):
    """Run the contended instance under ``spec`` with the fault-tolerant
    safety probe attached; returns the result (replay already checked)."""
    phase, a0 = _contended_instance()
    dead = set()
    pending = Counter()     # (holder, target) releases in flight
    slack = Counter()       # holder -> timeout aborts (peer unknown)

    def on_transfer(tasks, r_from, r_to):
        assert r_to not in dead, \
            f"transfer {r_from}->{r_to} targets a dead rank"

    hooked = [False]

    def probe(time, kind, src, dst, locks, state):
        if not hooked[0]:
            hooked[0] = True
            state.add_transfer_listener(on_transfer)
        if kind == FAIL:
            dead.add(dst)
            # the dead rank's lock state was reclaimed/force-released;
            # drop only ITS bookkeeping (other pairs' releases are still
            # genuinely in flight)
            for key in [k for k in pending if dst in k]:
                del pending[key]
            slack.pop(dst, None)
            return
        if kind == GRANT:
            pending[(dst, src)] += 1
        elif (kind == RELEASE and locks.locked_by[dst] != src
                and pending[(src, dst)] > 0):
            # only count the release as landed if it actually freed the
            # holder of record — a stale (token-mismatched) duplicate
            # must not spend the marker of a still-in-flight release
            pending[(src, dst)] -= 1
        elif kind == TIMEOUT:
            slack[dst] += 1
        for h in range(locks.n_ranks):
            live = [t for t in locks.held_by(h)
                    if pending[(h, t)] == 0]
            assert len(live) <= 1 + slack[h], \
                f"rank {h} holds live locks {live} at t={time}"

    res = ccm_lb_async(phase, a0, PARAMS, n_iter=n_iter, seed=seed,
                       fanout=6, latency=FAULT_LAT, max_retries=max_retries,
                       on_event=probe, fault=spec)
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    return res


def test_inactive_fault_spec_is_bitwise_noop():
    """The zero-fault parity bar: an all-inactive FaultSpec must add zero
    events, zero rng draws — bitwise-identical trace and trajectory."""
    phase, a0 = _contended_instance()
    kw = dict(n_iter=3, seed=3, fanout=6, latency=FAULT_LAT,
              collect_trace=True)
    ref = ccm_lb_async(phase, a0, PARAMS, **kw)
    res = ccm_lb_async(phase, a0, PARAMS, fault=FaultSpec(), **kw)
    assert not FaultSpec().active()
    np.testing.assert_array_equal(res.assignment, ref.assignment)
    assert res.transfer_log == ref.transfer_log
    assert res.events == ref.events
    assert res.max_work == ref.max_work
    assert res.fault_stats is None and res.dead_ranks is None


@pytest.mark.parametrize("spec", [
    FaultSpec(drop=0.05, seed=11),
    FaultSpec(dup=0.10, seed=12),
    FaultSpec(reorder=0.15, reorder_scale=2.0, seed=13),
    FaultSpec(drop=0.03, dup=0.05, reorder=0.05, seed=14),
], ids=["drop", "dup", "reorder", "combined"])
def test_protocol_safe_under_message_faults(spec):
    """Drop/dup/reorder sweeps: invariants hold, the hardening paths that
    MUST fire for each fault class actually fire, and nothing is lost."""
    res = _run_faulted(spec)
    fs = res.fault_stats
    assert fs is not None
    injected = fs.dropped + fs.duplicated + fs.reordered
    assert injected > 0, "the spec was supposed to inject faults"
    if fs.dropped:
        # lost REQ/GRANT/RELEASE messages surface as timeouts and/or
        # stage-end wedge reclaims — never as a hang or a lost transfer
        assert res.timeouts > 0
    if fs.duplicated:
        # duplicates are idempotent no-ops: token-checked at each handler
        assert (fs.dup_requests + fs.stale_grants + fs.stale_releases) > 0
    assert res.transfers > 0


def test_lost_messages_timeout_and_retry():
    """A heavy-drop link: requests time out, abort, retry with backoff —
    and the exhausted retries are counted, not silently dropped."""
    res = _run_faulted(FaultSpec(drop=0.15, req_timeout=2.0, seed=21),
                      max_retries=2)
    fs = res.fault_stats
    assert res.timeouts > 0
    assert fs.dropped > 0
    # aborts land as grant-frees, queue dequeues or stale no-ops
    assert (fs.aborted_dequeues + fs.stale_releases + fs.stale_grants
            + fs.wedged_reclaimed) > 0


def test_duplicate_storm_is_idempotent():
    """Every message duplicated half the time: the duplicate-REQ /
    stale-GRANT / stale-RELEASE paths all fire and the trajectory stays
    invariant-clean (the probe + replay in _run_faulted)."""
    res = _run_faulted(FaultSpec(dup=0.5, seed=22))
    fs = res.fault_stats
    assert fs.duplicated > 0
    assert fs.dup_requests > 0
    assert fs.stale_releases > 0


def test_max_retries_zero_terminates_under_faults():
    """The retry bound holds even when faults force timeouts: cap 0 means
    every timeout/yield drops its work item (counted), and the stage
    still drains to quiescence with the replay invariant intact."""
    res = _run_faulted(FaultSpec(drop=0.1, req_timeout=2.0, seed=23),
                      max_retries=0)
    assert res.timeouts > 0
    assert res.retries_exhausted > 0


def test_rank_death_reclamation_and_recovery():
    """Kills mid-iteration: the dead ranks' lock state is reclaimed, no
    task is stranded on them at the end, the recovery migrations are
    logged separately AND flow through the transfer log (replay covers
    them), and later iterations keep balancing the survivor set."""
    spec = FaultSpec(kill=((3, 1, 0.5), (7, 1, 3.0)), seed=24)
    res = _run_faulted(spec, n_iter=4)
    fs = res.fault_stats
    assert res.dead_ranks == [3, 7]
    assert fs.killed == 2
    assert not np.isin(res.assignment, res.dead_ranks).any()
    assert fs.recovered_tasks > 0
    assert res.recovery_log, "recovery migrations must be logged"
    for tasks, r_from, r_to in res.recovery_log:
        assert r_from in (3, 7) and r_to not in (3, 7)
        assert (tasks, r_from, r_to) in res.transfer_log
    # the balancer keeps improving on the survivors after the crash
    assert res.transfers > 0


def test_kill_under_message_loss():
    """The hard combination: a rank dies while messages are also being
    dropped — reclamation, timeouts and recovery must compose."""
    spec = FaultSpec(drop=0.05, kill=((5, 1, 1.0),), seed=25)
    res = _run_faulted(spec, n_iter=4)
    assert res.dead_ranks == [5]
    assert not np.isin(res.assignment, [5]).any()
    assert res.timeouts > 0


def test_all_ranks_dead_raises_rank_death():
    """Killing the whole set cannot be balanced away — it must raise the
    checkpoint-restart layer's NodeFailure vocabulary."""
    phase, a0 = _contended_instance()
    kill = tuple((r, 0, 0.5) for r in range(phase.num_ranks))
    with pytest.raises(RankDeath):
        ccm_lb_async(phase, a0, PARAMS, n_iter=2, seed=3,
                     latency=FAULT_LAT, fault=FaultSpec(kill=kill, seed=26))
    assert issubclass(RankDeath, NodeFailure)   # restart loops catch it


def test_pause_defers_delivery():
    """A paused rank receives nothing inside its window; deliveries are
    deferred to the window's end, not lost."""
    res = _run_faulted(FaultSpec(pause=((2, 0, 0.0, 8.0),
                                        (9, 1, 0.0, 5.0)), seed=27))
    assert res.fault_stats.paused_deferrals > 0


def test_livelock_error_is_structured():
    """The event-budget guard must carry the partial accounting (satellite
    bugfix): processed/queued counts, sim time, partial ProtocolStats and
    the iteration it died in — not a bare assertion that loses it all."""
    phase, a0 = _contended_instance()
    with pytest.raises(LivelockError) as ei:
        ccm_lb_async(phase, a0, PARAMS, n_iter=2, seed=3,
                     latency=FAULT_LAT, max_events=50,
                     fault=FaultSpec(drop=0.05, seed=28))
    e = ei.value
    assert isinstance(e, RuntimeError) and "events" in str(e)
    assert e.processed == e.max_events + 1 == 51
    assert e.queued >= 0 and e.sim_time >= 0.0
    assert e.stats is not None          # partial ProtocolStats attached
    assert e.fault_stats is not None
    assert e.iteration == 0


# ------------------------------------------- chaos suite: split brains,
# corruption, stage-1 deaths, elastic joins

def test_faultspec_validation_messages():
    """Stricter validate() (satellite): duplicate kills, overlapping pause
    windows, malformed partitions — each rejected with an actionable
    message, not a downstream KeyError."""
    with pytest.raises(ValueError, match="a rank dies once"):
        FaultSpec(kill=((3, 0, 0.5), (3, 1, 0.5))).validate(16, 4)
    with pytest.raises(ValueError, match="merge them into one window"):
        FaultSpec(pause=((2, 1, 0.0, 5.0), (2, 1, 4.0, 9.0))).validate(16, 4)
    # disjoint windows on the same rank/iteration are fine
    FaultSpec(pause=((2, 1, 0.0, 4.0), (2, 1, 4.0, 9.0))).validate(16, 4)
    with pytest.raises(ValueError, match="stage must be 1"):
        FaultSpec(kill=((3, 0, 0.5, 7),)).validate(16, 4)
    with pytest.raises(ValueError, match=r"expected \(rank, iteration"):
        FaultSpec(kill=((3, 0),)).validate(16, 4)
    with pytest.raises(ValueError, match="both sides of a split"):
        FaultSpec(partition=(((0, 1, 2), (2, 3), 0, 0.0, 5.0),)) \
            .validate(16, 4)
    with pytest.raises(ValueError, match=r"out of range \[0, 16\)"):
        FaultSpec(partition=(((0, 1), (2, 99), 0, 0.0, 5.0),)) \
            .validate(16, 4)
    with pytest.raises(ValueError, match="must be non-empty"):
        FaultSpec(partition=(((), (2, 3), 0, 0.0, 5.0),)).validate(16, 4)
    with pytest.raises(ValueError, match="0 <= start <= end"):
        FaultSpec(partition=(((0, 1), (2, 3), 0, 5.0, 1.0),)).validate(16, 4)
    with pytest.raises(ValueError, match="not in"):
        FaultSpec(corrupt=1.5).validate(16, 4)
    assert FaultSpec(partition=(((0,), (1,), 0, 0.0, 1.0),)).active()
    assert FaultSpec(corrupt=0.01).active()


def test_partition_healed_invariants():
    """A split-brain window over the gossip stage: cross-island messages
    are destroyed (counted), each island keeps making local progress, and
    after the heal the run re-merges with the replay/mutex invariants
    intact (the probe inside _run_faulted)."""
    half = tuple(range(8))
    other = tuple(range(8, 16))
    spec = FaultSpec(partition=((half, other, 0, 0.0, 15.0),), seed=41)
    res = _run_faulted(spec)
    fs = res.fault_stats
    assert fs.partitioned_dropped > 0, "the split never severed a message"
    assert res.transfers > 0           # islands still balanced locally


def test_partition_stage2_skip_accounting():
    """A split that opens only AFTER gossip drains: the work lists are
    global, so the DECIDE-time partition check must fire (skips counted,
    retry budget consumed) instead of burning the full timeout on every
    severed peer."""
    phase, a0 = _contended_instance()
    kw = dict(n_iter=2, seed=3, fanout=6, latency=FAULT_LAT)
    ref = ccm_lb_async(phase, a0, PARAMS, collect_trace=True, **kw)
    t_open = min(t for t, _, k, _, _ in ref.events if k == "DECIDE") - 1e-3
    spec = FaultSpec(partition=((tuple(range(8)), tuple(range(8, 16)),
                                 0, t_open, 1e9),), seed=42)
    res = ccm_lb_async(phase, a0, PARAMS, fault=spec, **kw)
    fs = res.fault_stats
    assert fs.partition_skips > 0
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)


def test_partition_livelock_payload():
    """Satellite: when a never-healing split plus an unbounded retry
    budget overflows the event budget, the LivelockError must carry the
    full post-mortem — iteration, processed/queued, partial stats and the
    partition_skips that explain WHY it ran hot."""
    phase = scaling_phase(16)
    a0 = initial_assignment(phase)
    kw = dict(n_iter=4, k_rounds=2, fanout=4, seed=0,
              latency=("uniform", 0.5, 1.5))
    ref = ccm_lb_async(phase, a0, PARAMS, collect_trace=True, **kw)
    t_open = min(t for t, _, k, _, _ in ref.events if k == "DECIDE") - 0.01
    spec = FaultSpec(partition=((tuple(range(8)), tuple(range(8, 16)),
                                 0, t_open, 1e9),), seed=5)
    with pytest.raises(LivelockError) as ei:
        ccm_lb_async(phase, a0, PARAMS, fault=spec, max_retries=200,
                     max_events=len(ref.events) + 500, **kw)
    e = ei.value
    assert e.processed == e.max_events + 1
    assert e.queued >= 0 and e.sim_time > 0.0
    assert e.iteration >= 0
    assert e.stats is not None
    assert e.fault_stats is not None
    assert e.fault_stats.partition_skips > 0, \
        "the post-mortem must show the partition churn that caused it"


def test_gossip_corruption_is_quarantined():
    """Every mutated payload must be caught by the checksum/stamp check:
    corrupted == corrupt_quarantined (nothing merged, nothing forwarded),
    and the balancer still converges off clean copies."""
    res = _run_faulted(FaultSpec(corrupt=0.15, seed=43))
    fs = res.fault_stats
    assert fs.corrupted > 0, "the corruption injector never fired"
    assert fs.corrupted == fs.corrupt_quarantined, \
        f"{fs.corrupted} corrupted but {fs.corrupt_quarantined} quarantined"
    assert res.transfers > 0


def test_stage1_kill_does_not_wedge_gossip():
    """A root dying MID-EPIDEMIC: the flood must drain without it, the
    survivors finish the iteration, and recovery strands nothing on the
    dead rank."""
    spec = FaultSpec(kill=((3, 1, 0.5, 1),), seed=44)
    res = _run_faulted(spec, n_iter=4)
    fs = res.fault_stats
    assert res.dead_ranks == [3]
    assert fs.killed == 1
    assert not (res.assignment == 3).any()
    assert fs.recovered_tasks > 0
    assert res.transfers > 0


def test_stage1_kill_all_ranks_raises_rank_death():
    """Killing every rank during the flood is unrecoverable and must
    surface as RankDeath from inside _run_gossip, not a hang."""
    phase, a0 = _contended_instance()
    kill = tuple((r, 0, 0.1, 1) for r in range(phase.num_ranks))
    with pytest.raises(RankDeath):
        ccm_lb_async(phase, a0, PARAMS, n_iter=2, seed=3,
                     latency=FAULT_LAT, fault=FaultSpec(kill=kill, seed=45))


def test_mid_stream_join_attracts_work():
    """Elastic growth: ranks joining at iteration 1 are folded into the
    mesh, inherit gossip state through the ordinary flood, and end the
    run owning real work — with the transfer log replaying cleanly across
    the membership change."""
    phase, a0 = _contended_instance()
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=3, fanout=6,
                       latency=FAULT_LAT,
                       membership=(RankJoin(iteration=1, count=2),))
    assert res.joined_ranks == [16, 17]
    assert res.state.phase.num_ranks == 18
    on_joined = int(np.isin(res.assignment, res.joined_ranks).sum())
    assert on_joined > 0, "joiners attracted no work"
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    # joins without faults leave fault accounting untouched
    assert res.fault_stats is None and res.dead_ranks is None


def test_crash_then_join_recovers():
    """Shrink then re-grow in one run: rank 3 dies at iteration 1, a
    replacement joins at iteration 2 — the dead rank stays empty, the
    joiner picks up work, and the log replays end to end."""
    phase, a0 = _contended_instance()
    spec = FaultSpec(kill=((3, 1, 0.5),), seed=46)
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=4, seed=3, fanout=6,
                       latency=FAULT_LAT, fault=spec,
                       membership=(RankJoin(iteration=2, count=1),))
    assert res.dead_ranks == [3]
    assert res.joined_ranks == [16]
    assert not (res.assignment == 3).any()
    assert res.fault_stats.recovered_tasks > 0
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)


def test_membership_validation():
    """Join events are validated up front with actionable errors."""
    phase, a0 = _contended_instance()
    with pytest.raises(ValueError, match="iteration out of range"):
        ccm_lb_async(phase, a0, PARAMS, n_iter=2,
                     membership=(RankJoin(iteration=5),))
    with pytest.raises(ValueError, match="iteration"):
        RankJoin(iteration=-1)
    with pytest.raises(ValueError, match="count"):
        RankJoin(iteration=0, count=0)
    with pytest.raises(ValueError, match="async-driver knob"):
        run_ccm_lb(phase, a0, PARAMS, async_mode=False,
                   membership=(RankJoin(iteration=0),))


def test_join_with_zero_latency_matches_rebuilt_baseline():
    """Determinism across the membership path: the same join schedule run
    twice is bitwise-identical (joins draw nothing from the fault rng)."""
    phase, a0 = _contended_instance()
    kw = dict(n_iter=3, seed=3, fanout=6, latency=FAULT_LAT,
              membership=(RankJoin(iteration=1, count=1),))
    r1 = ccm_lb_async(phase, a0, PARAMS, **kw)
    r2 = ccm_lb_async(phase, a0, PARAMS, **kw)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    assert r1.transfer_log == r2.transfer_log
    assert r1.joined_ranks == r2.joined_ranks


def test_fault_runs_are_deterministic():
    """The whole faulted run is a pure function of (instance, seed, spec):
    same spec -> identical trajectory, different fault seed -> (on this
    instance) a different one."""
    phase, a0 = _contended_instance()
    kw = dict(n_iter=3, seed=3, fanout=6, latency=FAULT_LAT)
    spec = FaultSpec(drop=0.05, dup=0.05, seed=31)
    r1 = ccm_lb_async(phase, a0, PARAMS, fault=spec, **kw)
    r2 = ccm_lb_async(phase, a0, PARAMS, fault=spec, **kw)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    assert r1.transfer_log == r2.transfer_log
    assert r1.fault_stats == r2.fault_stats     # FaultStats is a dataclass
    r3 = ccm_lb_async(phase, a0, PARAMS,
                      fault=FaultSpec(drop=0.05, dup=0.05, seed=32), **kw)
    assert r1.transfer_log != r3.transfer_log
