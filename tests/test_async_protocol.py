"""Property-based §IV-B protocol-safety suite for the async driver.

Invariants, checked after EVERY stage-2 event via the ``on_event`` probe
and at termination:

  * safety — each rank holds at most one lock at any time NET of releases
    in flight (a yielding/finished holder sends RELEASE and moves on; the
    target's ``locked_by`` keeps the old holder of record until the
    message arrives — correct message-passing behavior, so the probe
    reconstructs in-flight releases from the event stream), each rank is
    locked by at most one holder (single-slot ``locked_by``), and every
    transfer executes under mutual exclusion (asserted inside the driver
    itself: ``locked_by[p] == r`` at evaluation time);
  * no lost or duplicated transfers — the transfer log replays from the
    initial assignment to exactly the returned one (every entry's source
    rank must match at replay time, so duplication/loss both fail);
  * liveness / deadlock-freedom — the event loop terminates with all
    mailboxes drained and the lock table quiescent (asserted inside
    ``_run_stage2``; the ``max_events`` guard turns a non-terminating
    protocol bug into a loud RuntimeError instead of a hang);
  * optimizer contract — the per-iteration max-work trace is monotone and
    the final max work lands within a tolerance band of the synchronous
    result (empirically the worst observed ratio over the sweep space is
    ~1.03; the band asserts 1.15).

Plus the coverage-of-dead-branches pin: on a fixed contended instance the
async driver MUST produce lock conflicts, yields and a grant chain of
length >= 2 — so the §IV-B branches (structurally unreachable through the
synchronous round-robin drivers) can never silently go dead again.
"""
import numpy as np
import pytest

from repro.core import CCMParams, ccm_lb, ccm_lb_async, random_phase
from repro.core.async_sim import GRANT, RELEASE
from repro.core.problem import initial_assignment

PARAMS = CCMParams(delta=1e-9)
LATENCIES = (0.0, 0.2, ("uniform", 0.1, 0.6), ("uniform", 0.5, 1.5),
             ("exp", 0.7))


def _replay(a0: np.ndarray, transfer_log) -> np.ndarray:
    """Replay the mutation log; asserts every entry's source rank matches
    (a lost, duplicated or reordered-across-dependency transfer fails)."""
    a = a0.copy()
    for tasks, r_from, r_to in transfer_log:
        idx = list(tasks)
        assert (a[idx] == r_from).all(), \
            f"transfer {tasks}: {r_from}->{r_to} does not match replay state"
        a[idx] = r_to
    return a


def _check_protocol_safety(seed: int, lat_index: int):
    phase = random_phase(seed, num_ranks=8, num_tasks=160, num_blocks=20,
                         num_comms=320, mem_cap=1e12)
    a0 = initial_assignment(phase, "home" if seed % 2 else "round_robin")
    latency = LATENCIES[lat_index % len(LATENCIES)]
    events = [0]
    # (holder, target) pairs whose RELEASE is in flight: the grant handler
    # always sends RELEASE before returning (kind 2 == GRANT), and the
    # release lands when its event processes (kind 3 == RELEASE)
    pending_release = set()

    def probe(time, kind, src, dst, locks, state):
        events[0] += 1
        if kind == GRANT:                   # processed at dst: holder moves
            pending_release.add((dst, src))  # on, its RELEASE is in flight
        elif kind == RELEASE:               # landed at dst
            pending_release.discard((src, dst))
        for h in range(locks.n_ranks):
            live = [t for t in locks.held_by(h)
                    if (h, t) not in pending_release]
            assert len(live) <= 1, \
                f"rank {h} holds live locks {live} at t={time}"

    res = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=seed,
                       latency=latency, on_event=probe)
    assert events[0] > 0
    # no lost/duplicated transfers: the log replays to the final assignment
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    assert len(res.transfer_log) >= res.transfers  # swaps log two entries
    # monotone improvement per iteration
    for a, b in zip(res.max_work, res.max_work[1:]):
        assert b <= a + 1e-9
    # tolerance band vs the synchronous trajectory
    ref = ccm_lb(phase, a0, PARAMS, n_iter=3, seed=seed)
    assert res.max_work[-1] <= ref.max_work[-1] * 1.15 + 1e-9, \
        (res.max_work[-1], ref.max_work[-1], latency)


# ---------------------------------------------------------- seeded fallback
@pytest.mark.parametrize("seed", range(10))
def test_protocol_safety_seeded(seed):
    """Seeded sweep of the property (always runs, hypothesis or not)."""
    _check_protocol_safety(seed, lat_index=seed)


try:  # hypothesis variant: wider seed/latency space with dev deps
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 100_000),
           lat_index=st.integers(0, len(LATENCIES) - 1))
    def test_protocol_safety_property(seed, lat_index):
        _check_protocol_safety(seed, lat_index)
except ImportError:  # pragma: no cover - exercised without dev deps
    pass


# --------------------------------------------------- dead-branch coverage
def _contended_instance():
    """Half the ranks start empty, so stage 1 points many loaded ranks at
    the same underloaded peers and latency windows overlap their lock
    requests — conflicts, yields and multi-hop grant chains all fire."""
    phase = random_phase(1, num_ranks=16, num_tasks=400, num_blocks=48,
                         num_comms=800, mem_cap=1e12)
    a0 = (np.arange(phase.num_tasks) % 8).astype(np.int64)
    return phase, a0


def test_dead_branches_are_reachable_async():
    """Coverage pin (satellite): the §IV-B branches must actually fire on
    this fixed seeded instance — lock conflicts, line-45 yields, and a
    grant chain of >= 2 consecutive queue handoffs."""
    phase, a0 = _contended_instance()
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=4, seed=3, fanout=6,
                       latency=("uniform", 0.5, 1.5))
    assert res.lock_conflicts > 0
    assert res.yields > 0
    assert res.grant_chains > 0
    assert res.max_grant_chain >= 2
    assert res.transfers > 0
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
    # and the balancer still does its job under contention
    assert res.imbalance[-1] < res.imbalance[0] * 0.5


def test_sync_driver_conflicts_structurally_zero():
    """The documented limitation the async driver exists to close: the
    synchronous round-robin loop releases every lock within the turn that
    took it, so even the contended instance cannot produce conflicts,
    yields or chains there (uniform accounting via the shared handlers)."""
    phase, a0 = _contended_instance()
    for kw in (dict(), dict(use_engine=False), dict(batch_lock_events=8)):
        res = ccm_lb(phase, a0, PARAMS, n_iter=4, seed=3, fanout=6, **kw)
        assert res.lock_conflicts == 0
        assert res.yields == 0
        assert res.grant_chains == 0 and res.max_grant_chain == 0


def test_max_events_guard_raises_not_hangs():
    """A liveness bug must surface as RuntimeError, not a silent hang."""
    phase, a0 = _contended_instance()
    with pytest.raises(RuntimeError, match="events"):
        ccm_lb_async(phase, a0, PARAMS, n_iter=2, seed=3,
                     latency=("uniform", 0.5, 1.5), max_events=50)


def test_yield_retries_are_bounded():
    """max_retries bounds re-queues: with zero retries allowed a yielding
    rank drops the attempt instead of looping, and the run still
    terminates safely."""
    phase, a0 = _contended_instance()
    res = ccm_lb_async(phase, a0, PARAMS, n_iter=3, seed=3, fanout=6,
                       latency=("uniform", 0.5, 1.5), max_retries=0)
    assert res.yields > 0
    np.testing.assert_array_equal(_replay(a0, res.transfer_log),
                                  res.assignment)
