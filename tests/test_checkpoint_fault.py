"""Checkpointing (atomicity, roundtrip, GC) and fault-tolerant restart:
a run killed mid-training and restarted must reproduce the uninterrupted
run's loss trajectory exactly."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop
from repro.runtime.fault import (FaultInjector, NodeFailure, RankDeath,
                                 run_with_restarts)

MESH = make_local_mesh(1, 1)


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    got = restore(tmp_path, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    """A tmp dir must never be picked up as a checkpoint."""
    (tmp_path / ".tmp_step_00000009").mkdir(parents=True)
    assert latest_step(tmp_path) is None
    save(tmp_path, 3, _tree())
    assert latest_step(tmp_path) == 3


def test_manager_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    restored, step = mgr.restore(_tree())
    assert step == 4


def test_structure_change_rejected(tmp_path):
    save(tmp_path, 1, _tree())
    bad = {"a": jnp.zeros((8, 16))}
    with pytest.raises(AssertionError):
        restore(tmp_path, 1, bad)


def test_latest_step_waits_for_interrupted_async_save(tmp_path, monkeypatch):
    """Regression: an async save still in flight when its manager is
    abandoned (the crash-restart path) must be visible to a FRESH reader —
    ``latest_step`` has to join the registered writer thread instead of
    returning None and silently replaying from step 0."""
    import time

    import repro.checkpoint.checkpoint as ckpt

    orig_save = ckpt.save

    def slow_save(*args, **kwargs):
        time.sleep(0.5)  # guarantee the reader races ahead of the rename
        return orig_save(*args, **kwargs)

    monkeypatch.setattr(ckpt, "save", slow_save)
    mgr = CheckpointManager(tmp_path, async_write=True)
    mgr.save(3, _tree())
    # simulate the crashed run: mgr is never wait()ed or used again
    assert latest_step(tmp_path) == 3
    fresh = CheckpointManager(tmp_path, async_write=True)
    assert fresh.latest() == 3
    restored, step = fresh.restore(_tree())
    assert step == 3


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Deterministic data + atomic checkpoints => restarted == straight run."""
    cfg = configs.get_smoke_config("tinyllama-1.1b")
    common = dict(steps=9, seq_len=32, global_batch=2, ckpt_every=3,
                  log_every=100, seed=0)

    # uninterrupted reference
    _, _, ref_losses = train_loop(cfg, MESH, ckpt_dir=str(tmp_path / "ref"),
                                  **common)

    # interrupted at step 5 (after the step-3 checkpoint), then restarted
    inj = FaultInjector(fail_at_steps=(5,))
    losses_parts = []

    def once():
        _, _, losses = train_loop(cfg, MESH, ckpt_dir=str(tmp_path / "ft"),
                                  fault=inj, **common)
        losses_parts.append(losses)

    stats = run_with_restarts(once)
    assert stats.completed and stats.restarts == 1
    # the restarted segment covers steps 3..8; compare overlap exactly
    restarted = losses_parts[-1]
    np.testing.assert_allclose(restarted, ref_losses[3:], rtol=1e-6)


def test_run_with_restarts_gives_up_after_max_restarts():
    """Satellite: the max_restarts-exceeded path — a driver that never
    stops failing must come back ``completed=False`` with the attempt
    count intact (max_restarts + 1 failures: the initial try plus one per
    allowed restart), not loop forever or raise out of the wrapper."""
    calls = []

    def always_fails():
        calls.append(1)
        raise NodeFailure("permanent")

    stats = run_with_restarts(always_fails, max_restarts=3)
    assert not stats.completed
    assert stats.restarts == 4          # gave up on the 4th failure
    assert len(calls) == 4              # initial attempt + 3 restarts
    assert stats.wall_s >= 0.0

    # max_restarts=0: one attempt, zero retries
    calls.clear()
    stats = run_with_restarts(always_fails, max_restarts=0)
    assert not stats.completed and len(calls) == 1 and stats.restarts == 1

    # RankDeath (async-harness total loss) rides the same policy
    def all_ranks_die():
        calls.append(1)
        raise RankDeath("every rank dead")

    calls.clear()
    stats = run_with_restarts(all_ranks_die, max_restarts=2)
    assert not stats.completed and len(calls) == 3


def test_run_with_restarts_honors_backoff(monkeypatch):
    """backoff_s sleeps between failures — but never after the final
    give-up failure, and never when backoff is zero."""
    import repro.runtime.fault as fault_mod

    naps = []
    monkeypatch.setattr(fault_mod.time, "sleep", lambda s: naps.append(s))

    attempts = []

    def fails_twice_then_succeeds():
        attempts.append(1)
        if len(attempts) < 3:
            raise NodeFailure("transient")

    stats = run_with_restarts(fails_twice_then_succeeds, max_restarts=5,
                              backoff_s=0.25)
    assert stats.completed and stats.restarts == 2
    assert naps == [0.25, 0.25]         # one nap per restart taken

    naps.clear()
    stats = run_with_restarts(lambda: (_ for _ in ()).throw(
        NodeFailure("permanent")), max_restarts=2, backoff_s=0.5)
    assert not stats.completed
    assert naps == [0.5, 0.5]           # no sleep after the give-up

    naps.clear()
    attempts.clear()
    run_with_restarts(fails_twice_then_succeeds, max_restarts=5)
    assert naps == []                   # backoff_s=0.0 never sleeps


def test_elastic_restore_other_mesh(tmp_path):
    """Same checkpoint restores under different mesh shardings (1x1 here;
    the 512-device variant is exercised by the dry-run subprocess test)."""
    from repro.launch.steps import abstract_params
    from repro.models.layers import split_lp_tree
    from repro.models.model import build_model

    cfg = configs.get_smoke_config("smollm-360m")
    model = build_model(cfg, MESH)
    params, _ = split_lp_tree(model.init(jax.random.key(0)))
    save(tmp_path, 1, params)

    mesh2 = make_local_mesh(1, 1)
    model2 = build_model(cfg, mesh2)
    sds, sh = abstract_params(model2)
    got = restore(tmp_path, 1, sds, sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
